//! Minimal JSON parser/serialiser (RFC 8259 subset sufficient for the
//! `.dfqm`/`.dfqd` headers and `manifest.json`).
//!
//! No serde in the offline crate set, so this is first-party. The parser
//! is a straightforward recursive-descent over bytes; numbers are f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    pub fn as_shape(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Serialise (compact). Keys are emitted in BTreeMap order.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs unsupported (not emitted by
                            // the python writer); map to replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // re-decode multibyte UTF-8
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.b.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..end])?);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|_| {
            anyhow!("bad number '{s}' at byte {start}")
        })?))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.req("c").unwrap().as_str().unwrap(), "x");
        let arr = j.req("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize().unwrap(), 2);
        assert!(arr[2].req("b").unwrap().is_null());
    }

    #[test]
    fn parse_unicode() {
        let j = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "café ☕");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,null,true],"s":"he\"llo","z":{"k":-1}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{\"a\":1} extra").is_err());
    }

    #[test]
    fn shape_accessor() {
        let j = Json::parse("[4, 3, 32, 32]").unwrap();
        assert_eq!(j.as_shape().unwrap(), vec![4, 3, 32, 32]);
        assert!(Json::parse("[1.5]").unwrap().as_shape().is_err());
    }
}
