//! Minimal read-only memory mapping for zero-copy artifact loads.
//!
//! On unix targets [`Mmap::map`] maps the file with raw `mmap(2)` FFI —
//! no external crate, the same vendoring discipline as `vendor/anyhow`.
//! Elsewhere (and for empty files, which `mmap` rejects) it degrades to
//! reading the file into an owned `Vec<u8>` with identical observable
//! behaviour. [`ArcSlice`] layers a cheaply-cloneable typed slice on
//! top: either an owned `Vec<T>`, or a `(Arc<Mmap>, offset, len)` view
//! that keeps the mapping alive for as long as any tensor borrows from
//! it — the page cache holds the weights; eviction drops only plan
//! structs.

use std::fmt;
use std::fs;
use std::io;
use std::ops::Deref;
use std::path::Path;
use std::sync::Arc;

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }

    /// `MAP_FAILED` is `(void*)-1` on every unix.
    pub fn map_failed() -> *mut c_void {
        usize::MAX as *mut c_void
    }
}

/// A read-only byte view of a whole file: page-cache-backed on unix,
/// an owned read elsewhere. Dereferences to `&[u8]`.
pub struct Mmap {
    inner: Inner,
}

enum Inner {
    Owned(Vec<u8>),
    #[cfg(unix)]
    Mapped { ptr: *const u8, len: usize },
}

// SAFETY: a `Mapped` region is PROT_READ + MAP_PRIVATE — nothing can
// write through it, and the kernel keeps the pages valid until the
// `munmap` that only `Drop` issues. Shrinking the underlying file
// while mapped is the one hazard (SIGBUS on a faulted-out page), which
// is inherent to mmap'd IO and documented at the artifact API.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map `path` read-only.
    pub fn map(path: &Path) -> io::Result<Mmap> {
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            let file = fs::File::open(path)?;
            let len = file.metadata()?.len();
            if len == 0 {
                // zero-length mmap is EINVAL; an empty Vec is identical
                return Ok(Mmap { inner: Inner::Owned(Vec::new()) });
            }
            if len > usize::MAX as u64 {
                return Err(io::Error::new(
                    io::ErrorKind::OutOfMemory,
                    "file larger than the address space",
                ));
            }
            let len = len as usize;
            // SAFETY: fd is a freshly opened readable file; a private
            // read-only mapping of it aliases nothing we hand out
            // mutably.
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr == sys::map_failed() || ptr.is_null() {
                return Err(io::Error::last_os_error());
            }
            // the fd may close now: the mapping holds its own reference
            Ok(Mmap { inner: Inner::Mapped { ptr: ptr as *const u8, len } })
        }
        #[cfg(not(unix))]
        {
            Ok(Mmap { inner: Inner::Owned(fs::read(path)?) })
        }
    }

    /// Read `path` into an owned buffer behind the same interface —
    /// the forced fallback path (`DFQ_NO_MMAP`, CI pinning).
    pub fn read(path: &Path) -> io::Result<Mmap> {
        Ok(Mmap { inner: Inner::Owned(fs::read(path)?) })
    }

    /// Whether the bytes are truly page-cache-backed (vs the owned
    /// fallback).
    pub fn is_mapped(&self) -> bool {
        match &self.inner {
            Inner::Owned(_) => false,
            #[cfg(unix)]
            Inner::Mapped { .. } => true,
        }
    }
}

impl Deref for Mmap {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        match &self.inner {
            Inner::Owned(v) => v,
            #[cfg(unix)]
            Inner::Mapped { ptr, len } => {
                // SAFETY: `ptr` is a live PROT_READ mapping of exactly
                // `len` bytes (see `map`), unmapped only on drop.
                unsafe { std::slice::from_raw_parts(*ptr, *len) }
            }
        }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Inner::Mapped { ptr, len } = self.inner {
            // SAFETY: this pointer/len pair came from a successful
            // `mmap` and is unmapped exactly once.
            unsafe {
                sys::munmap(ptr as *mut std::os::raw::c_void, len);
            }
        }
    }
}

impl fmt::Debug for Mmap {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mmap({} B, mapped={})", self.len(), self.is_mapped())
    }
}

/// A cheaply-cloneable slice of plain little-endian integer data
/// (`i8`, `i64`, ...): either an owned `Vec<T>`, or a typed view into
/// an [`Mmap`] kept alive by the `Arc`. Dereferences to `&[T]`, so
/// every existing `&[T]` call site works by coercion.
#[derive(Clone)]
pub enum ArcSlice<T: Copy + 'static> {
    Owned(Vec<T>),
    Mapped {
        map: Arc<Mmap>,
        /// Byte offset of element 0 inside the mapping.
        off: usize,
        /// Element count.
        len: usize,
    },
}

impl<T: Copy + 'static> ArcSlice<T> {
    /// A typed view of `len` elements at byte offset `off` inside the
    /// mapping. Returns `None` when the range escapes the mapping (the
    /// caller turns that into a typed artifact error). A misaligned
    /// base — possible only through the owned-read fallback, whose
    /// `Vec<u8>` has no alignment guarantee — degrades to an owned
    /// element-wise copy rather than failing.
    ///
    /// Only sound for plain integer `T` whose in-file bytes are the
    /// host representation (little-endian targets; the artifact reader
    /// gates on `cfg!(target_endian)`).
    pub fn view(map: &Arc<Mmap>, off: usize, len: usize) -> Option<ArcSlice<T>> {
        let bytes = len.checked_mul(std::mem::size_of::<T>())?;
        let end = off.checked_add(bytes)?;
        if end > map.len() {
            return None;
        }
        // SAFETY: off..end is in bounds (checked above).
        let ptr = unsafe { map.as_ptr().add(off) } as *const T;
        if (ptr as usize) % std::mem::align_of::<T>() == 0 {
            Some(ArcSlice::Mapped { map: Arc::clone(map), off, len })
        } else {
            let mut v = Vec::with_capacity(len);
            for i in 0..len {
                // SAFETY: every element lies inside the checked range;
                // unaligned reads of plain integers are always valid.
                v.push(unsafe { std::ptr::read_unaligned(ptr.add(i)) });
            }
            Some(ArcSlice::Owned(v))
        }
    }

    /// Whether this slice borrows from a live mapping (vs owning).
    pub fn is_view(&self) -> bool {
        matches!(self, ArcSlice::Mapped { .. })
    }
}

impl<T: Copy + 'static> Deref for ArcSlice<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        match self {
            ArcSlice::Owned(v) => v,
            ArcSlice::Mapped { map, off, len } => {
                // SAFETY: bounds and alignment were checked in `view`;
                // the `Arc` keeps the mapping alive for `&self`'s
                // lifetime.
                unsafe {
                    std::slice::from_raw_parts(
                        map.as_ptr().add(*off) as *const T,
                        *len,
                    )
                }
            }
        }
    }
}

impl<T: Copy + 'static> Default for ArcSlice<T> {
    fn default() -> Self {
        ArcSlice::Owned(Vec::new())
    }
}

impl<T: Copy + 'static> From<Vec<T>> for ArcSlice<T> {
    fn from(v: Vec<T>) -> Self {
        ArcSlice::Owned(v)
    }
}

impl<T: Copy + fmt::Debug + 'static> fmt::Debug for ArcSlice<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let tag = if self.is_view() { "view" } else { "owned" };
        write!(f, "ArcSlice::{tag}({} elems)", self.len())
    }
}

impl<T: Copy + PartialEq + 'static> PartialEq for ArcSlice<T> {
    fn eq(&self, other: &Self) -> bool {
        self[..] == other[..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_file(tag: &str, bytes: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir()
            .join(format!("dfq_mmap_{tag}_{}", std::process::id()));
        fs::write(&p, bytes).unwrap();
        p
    }

    #[test]
    fn mapped_bytes_match_read_bytes() {
        let data: Vec<u8> = (0..=255u8).cycle().take(5000).collect();
        let p = temp_file("bytes", &data);
        let m = Mmap::map(&p).unwrap();
        assert_eq!(&m[..], &data[..]);
        #[cfg(unix)]
        assert!(m.is_mapped());
        let owned = Mmap::read(&p).unwrap();
        assert!(!owned.is_mapped());
        assert_eq!(&owned[..], &data[..]);
        fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_file_maps_to_empty_slice() {
        let p = temp_file("empty", &[]);
        let m = Mmap::map(&p).unwrap();
        assert_eq!(m.len(), 0);
        assert!(!m.is_mapped(), "empty files use the owned fallback");
        fs::remove_file(&p).ok();
    }

    #[test]
    fn missing_file_is_io_error() {
        assert!(Mmap::map(Path::new("/no/such/dfq_mmap_file")).is_err());
    }

    #[test]
    fn typed_views_and_bounds() {
        let mut bytes = Vec::new();
        for v in [1i64, -2, 3_000_000_000, i64::MIN] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let p = temp_file("views", &bytes);
        let m = Arc::new(Mmap::map(&p).unwrap());
        let s: ArcSlice<i64> = ArcSlice::view(&m, 0, 4).unwrap();
        assert_eq!(&s[..], &[1, -2, 3_000_000_000, i64::MIN]);
        let b: ArcSlice<i8> = ArcSlice::view(&m, 0, 32).unwrap();
        assert_eq!(b.len(), 32);
        assert!(ArcSlice::<i64>::view(&m, 0, 5).is_none(), "past the end");
        assert!(ArcSlice::<i8>::view(&m, 33, 1).is_none(), "bad offset");
        // a clone keeps the mapping alive after the original drops
        let c = s.clone();
        drop(s);
        drop(m);
        assert_eq!(c[3], i64::MIN);
        fs::remove_file(&p).ok();
    }

    #[test]
    fn misaligned_view_degrades_to_owned_copy() {
        let mut bytes = vec![0u8; 4]; // shift i64 payload off alignment
        bytes.extend_from_slice(&(-7i64).to_le_bytes());
        let p = temp_file("misaligned", &bytes);
        let m = Arc::new(Mmap::map(&p).unwrap());
        let s: ArcSlice<i64> = ArcSlice::view(&m, 4, 1).unwrap();
        assert!(!s.is_view());
        assert_eq!(s[0], -7);
        fs::remove_file(&p).ok();
    }

    #[test]
    fn owned_round_trip() {
        let s: ArcSlice<i8> = vec![1i8, -2, 3].into();
        assert_eq!(&s[..], &[1, -2, 3]);
        assert!(!s.is_view());
        assert_eq!(s, s.clone());
        assert_eq!(ArcSlice::<i8>::default().len(), 0);
    }

    #[test]
    fn mmap_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Mmap>();
        assert_send_sync::<ArcSlice<i8>>();
        assert_send_sync::<ArcSlice<i64>>();
    }
}
