//! Substrate utilities built in-repo (the image has no network access to
//! crates.io beyond `xla`/`anyhow`, so JSON, RNG, statistics, a thread
//! pool and the bench harness are all first-party — see DESIGN.md §3).

pub mod align;
pub mod bench;
pub mod json;
pub mod mmap;
pub mod parallel;
pub mod rng;
pub mod stats;
pub mod table;
