//! ASCII table / CSV emitters for the experiment drivers — every paper
//! table is printed through this so `cargo bench`/CLI output lines up.

/// A simple column-aligned table with a title.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Table {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> =
            self.header.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = format!("\n## {}\n", self.title);
        let line = |cells: &[String], w: &[usize]| -> String {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<width$} | ", c, width = w[i]));
            }
            s.trim_end().to_string()
        };
        out.push_str(&line(&self.header, &w));
        out.push('\n');
        let mut sep = String::from("|");
        for wi in &w {
            sep.push_str(&"-".repeat(wi + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &w));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// CSV rendering (for figure series consumed by plotting tools).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }

    /// Write the CSV next to the artifacts (results/ directory).
    pub fn save_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// Format a fraction as a percentage with 2 decimals (paper style).
pub fn pct(x: f64) -> String {
    format!("{:.2}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["model", "acc"]);
        t.rowf(&["orig", "71.72%"]);
        t.rowf(&["dfq", "71.19%"]);
        let s = t.render();
        assert!(s.contains("## T"));
        assert!(s.contains("| model | acc"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new("x", &["a", "b"]);
        t.rowf(&["1", "2"]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(0.7172), "71.72%");
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a"]);
        t.rowf(&["1", "2"]);
    }
}
