//! Deterministic PRNG (SplitMix64) — substrate for property tests, the
//! serving workload generator and synthetic tensors. First-party because
//! the offline crate set has no `rand`.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes; fully
/// deterministic across platforms.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9e3779b97f4a7c15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Log-uniform in [lo, hi); both must be positive.
    pub fn log_uniform(&mut self, lo: f32, hi: f32) -> f32 {
        debug_assert!(lo > 0.0 && hi >= lo);
        (self.uniform(lo.ln(), hi.ln())).exp()
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos())
            as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Fill a vec with iid normals scaled by `std`.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Exponentially distributed with rate `lambda` (inter-arrival times).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.f64()).ln() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn log_uniform_range() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.log_uniform(0.1, 10.0);
            assert!((0.1..10.0).contains(&x));
        }
    }
}
