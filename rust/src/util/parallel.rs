//! Scoped data-parallel helpers over `std::thread` (no rayon offline).
//!
//! On this testbed `available_parallelism() == 1`, so these degrade to a
//! sequential loop with zero thread overhead; on multi-core hosts they
//! chunk work across scoped threads.

use std::cell::Cell;
use std::num::NonZeroUsize;

/// Number of worker threads to use.
pub fn workers() -> usize {
    std::thread::available_parallelism()
        .map(NonZeroUsize::get)
        .unwrap_or(1)
}

thread_local! {
    /// When set, the data-parallel helpers degrade to serial on this
    /// thread — outer fan-outs flip it so nested kernels don't spawn
    /// workers² threads.
    static NESTED_SERIAL: Cell<bool> = Cell::new(false);
}

/// Run `f` with [`par_chunks`]/[`par_map`] degraded to serial *on this
/// thread*: an outer parallel fan-out (e.g. batch-parallel model
/// execution) wraps each arm in this so inner kernels don't multiply
/// the thread count. Note the flag is thread-local — set it inside the
/// worker closure, not around the outer `par_map` call.
pub fn with_nested_serial<T>(f: impl FnOnce() -> T) -> T {
    NESTED_SERIAL.with(|s| {
        let prev = s.replace(true);
        let out = f();
        s.set(prev);
        out
    })
}

/// Apply `f(start, end)` over disjoint chunks of `0..n` in parallel.
pub fn par_chunks<F>(n: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let w = if NESTED_SERIAL.with(Cell::get) { 1 } else { workers() }
        .min(n.max(1));
    if w <= 1 || n == 0 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(w);
    std::thread::scope(|s| {
        for t in 0..w {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo, hi));
        }
    });
}

/// Parallel map over `0..n` producing a `Vec<T>`.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots = as_send_cells(&mut out);
        par_chunks(n, |lo, hi| {
            for i in lo..hi {
                // SAFETY: each index is written by exactly one chunk.
                unsafe { *slots.get(i) = f(i) };
            }
        });
    }
    out
}

/// Shared mutable access where disjoint-index writes are guaranteed by
/// the caller (par_chunks hands out disjoint ranges).
pub struct SendCells<T>(*mut T);
unsafe impl<T> Sync for SendCells<T> {}
impl<T> SendCells<T> {
    /// # Safety
    /// Caller must ensure no two threads touch the same index.
    pub unsafe fn get(&self, i: usize) -> &mut T {
        unsafe { &mut *self.0.add(i) }
    }

    /// # Safety
    /// Caller must ensure no two threads touch overlapping ranges.
    pub unsafe fn slice(&self, start: usize, len: usize) -> &mut [T] {
        unsafe { std::slice::from_raw_parts_mut(self.0.add(start), len) }
    }

    /// Raw element pointer (for strided SIMD tile stores where a
    /// contiguous slice cannot express the aliasing pattern).
    ///
    /// # Safety
    /// Caller must ensure no two threads write overlapping elements.
    pub unsafe fn ptr_at(&self, i: usize) -> *mut T {
        unsafe { self.0.add(i) }
    }
}

pub fn as_send_cells<T>(v: &mut [T]) -> SendCells<T> {
    SendCells(v.as_mut_ptr())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let got = par_map(1000, |i| i * i);
        let want: Vec<usize> = (0..1000).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn par_chunks_covers_all() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let hits = AtomicUsize::new(0);
        par_chunks(317, |lo, hi| {
            hits.fetch_add(hi - lo, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 317);
    }

    #[test]
    fn nested_serial_matches_parallel() {
        let par = par_map(257, |i| i * 3);
        let ser = with_nested_serial(|| par_map(257, |i| i * 3));
        assert_eq!(par, ser);
        // the flag is scoped: parallelism is restored afterwards
        assert_eq!(par_map(5, |i| i), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn empty_range() {
        par_chunks(0, |lo, hi| assert_eq!(lo, hi, "no work expected"));
        let v: Vec<u8> = par_map(0, |_| 1u8);
        assert!(v.is_empty());
    }
}
