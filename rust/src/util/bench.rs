//! First-party micro/macro-bench harness (criterion is not in the
//! offline crate set). Used by every target in `rust/benches/`.
//!
//! Protocol per benchmark: warm-up runs, then timed iterations until both
//! a minimum iteration count and a minimum wall budget are met; reports
//! mean/p50/p95 and derived throughput. Honors two env vars:
//! `DFQ_BENCH_FAST=1` (single iteration — used by `cargo test` smoke) and
//! `DFQ_BENCH_SECS` (wall budget per bench).

use std::time::{Duration, Instant};

use super::stats::Summary;

pub struct Bench {
    name: String,
    min_iters: usize,
    budget: Duration,
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub secs: Summary,
    /// Optional units processed per iteration (for throughput lines).
    pub units: Option<(f64, &'static str)>,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Bench {
        let fast = std::env::var("DFQ_BENCH_FAST").ok().as_deref() == Some("1");
        let secs: f64 = std::env::var("DFQ_BENCH_SECS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(2.0);
        Bench {
            name: name.into(),
            min_iters: if fast { 1 } else { 10 },
            budget: Duration::from_secs_f64(if fast { 0.0 } else { secs }),
        }
    }

    pub fn with_min_iters(mut self, n: usize) -> Self {
        // fast mode (min_iters == 1) always wins
        if self.min_iters > 1 {
            self.min_iters = n.max(1);
        }
        self
    }

    /// Run `f` repeatedly; returns timing summary.
    pub fn run<F: FnMut()>(&self, mut f: F) -> BenchResult {
        // warm-up (compilation, caches, page faults)
        let warmups = self.min_iters.min(3);
        for _ in 0..warmups {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        loop {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
            if samples.len() >= self.min_iters && start.elapsed() >= self.budget
            {
                break;
            }
            if samples.len() >= 10_000 {
                break;
            }
        }
        BenchResult {
            name: self.name.clone(),
            secs: Summary::of(&samples),
            units: None,
        }
    }
}

impl BenchResult {
    pub fn with_units(mut self, per_iter: f64, label: &'static str) -> Self {
        self.units = Some((per_iter, label));
        self
    }

    pub fn report(&self) -> String {
        let s = &self.secs;
        let mut line = format!(
            "{:<44} {:>9} iters  mean {:>10}  p50 {:>10}  p95 {:>10}",
            self.name,
            s.n,
            fmt_secs(s.mean),
            fmt_secs(s.p50),
            fmt_secs(s.p95),
        );
        if let Some((units, label)) = self.units {
            line.push_str(&format!("  {:>12.1} {label}/s", units / s.mean));
        }
        line
    }

    pub fn print(&self) -> &Self {
        println!("{}", self.report());
        self
    }

    /// One-line machine-readable record (the bench JSON format shared by
    /// `benches/engine.rs` and `benches/qengine.rs`; throughput is in
    /// `units`/s when units were attached).
    pub fn json(&self) -> String {
        let s = &self.secs;
        let mut line = format!(
            "{{\"name\":{:?},\"iters\":{},\"mean_s\":{:e},\"p50_s\":{:e},\
             \"p95_s\":{:e}",
            self.name, s.n, s.mean, s.p50, s.p95
        );
        if let Some((units, label)) = self.units {
            line.push_str(&format!(
                ",\"units\":{:?},\"throughput\":{:e}",
                label,
                units / s.mean
            ));
        }
        line.push('}');
        line
    }

    /// Print the JSON record (stdout, one line).
    pub fn print_json(&self) -> &Self {
        println!("{}", self.json());
        self
    }
}

pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Print a section header for a bench binary.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        std::env::set_var("DFQ_BENCH_FAST", "1");
        let r = Bench::new("noop").run(|| {
            std::hint::black_box(1 + 1);
        });
        assert!(r.secs.n >= 1);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn json_record_shape() {
        std::env::set_var("DFQ_BENCH_FAST", "1");
        let r = Bench::new("jtest")
            .run(|| {
                std::hint::black_box(1 + 1);
            })
            .with_units(100.0, "flop");
        let j = r.json();
        assert!(j.starts_with('{') && j.ends_with('}'), "{j}");
        for key in ["\"name\"", "\"mean_s\"", "\"throughput\""] {
            assert!(j.contains(key), "{j} missing {key}");
        }
    }

    #[test]
    fn fmt_secs_scales() {
        assert!(fmt_secs(2e-9).contains("ns"));
        assert!(fmt_secs(2e-6).contains("µs"));
        assert!(fmt_secs(2e-3).contains("ms"));
        assert!(fmt_secs(2.0).contains(" s"));
    }
}
