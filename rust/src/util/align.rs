//! `AVec<T>`: a growable buffer whose allocation is always 64-byte
//! aligned (one x86 cache line / the widest vector register this crate
//! targets). The qengine scratch arenas and packed GEMM panels live in
//! these so SIMD loads never straddle a cache line and aligned
//! load/store intrinsics stay legal regardless of how the pool was
//! grown or reused.
//!
//! Deliberately tiny: `Deref`/`DerefMut` to `[T]` plus `resize`, which
//! is the only mutation the scratch pools use. `T: Copy` keeps drop
//! handling trivial (no element destructors to run on truncate).

use std::alloc::{alloc, dealloc, handle_alloc_error, Layout};
use std::ops::{Deref, DerefMut};
use std::ptr::NonNull;

/// Allocation alignment in bytes. 64 covers AVX-512-width loads and is
/// exactly one cache line on every target we dispatch for.
pub const ALIGN: usize = 64;

/// A 64-byte-aligned growable buffer of `Copy` elements.
///
/// An empty `AVec` owns no allocation (the pointer is dangling, as in
/// `Vec`); alignment is guaranteed for any buffer with capacity.
pub struct AVec<T: Copy> {
    ptr: NonNull<T>,
    len: usize,
    cap: usize,
}

// SAFETY: AVec owns its buffer exactly like Vec<T>; sharing/sending it
// is as safe as sharing/sending the underlying Ts.
unsafe impl<T: Copy + Send> Send for AVec<T> {}
unsafe impl<T: Copy + Sync> Sync for AVec<T> {}

impl<T: Copy> AVec<T> {
    pub const fn new() -> AVec<T> {
        AVec { ptr: NonNull::dangling(), len: 0, cap: 0 }
    }

    pub fn with_len(len: usize, fill: T) -> AVec<T> {
        let mut v = AVec::new();
        v.resize(len, fill);
        v
    }

    fn layout(cap: usize) -> Layout {
        // align_of::<T>() <= ALIGN for every T this crate stores (u8,
        // i8, i16, i32); the stricter 64-byte bound subsumes it.
        assert!(std::mem::align_of::<T>() <= ALIGN);
        Layout::from_size_align(cap * std::mem::size_of::<T>(), ALIGN)
            .expect("AVec capacity overflows Layout")
    }

    /// Resize to `len` elements, filling any newly exposed tail with
    /// `fill`. Shrinking truncates without releasing capacity (the
    /// scratch pools rely on that for allocation-free reuse).
    pub fn resize(&mut self, len: usize, fill: T) {
        if len > self.cap {
            let new_cap = len.max(self.cap * 2).max(8);
            let new_ptr = unsafe { alloc(Self::layout(new_cap)) } as *mut T;
            let Some(nn) = NonNull::new(new_ptr) else {
                handle_alloc_error(Self::layout(new_cap));
            };
            if self.cap > 0 {
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        self.ptr.as_ptr(),
                        nn.as_ptr(),
                        self.len,
                    );
                    dealloc(
                        self.ptr.as_ptr() as *mut u8,
                        Self::layout(self.cap),
                    );
                }
            }
            self.ptr = nn;
            self.cap = new_cap;
        }
        if len > self.len {
            for i in self.len..len {
                unsafe { self.ptr.as_ptr().add(i).write(fill) };
            }
        }
        self.len = len;
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_ptr(&self) -> *const T {
        self.ptr.as_ptr()
    }

    pub fn as_mut_ptr(&mut self) -> *mut T {
        self.ptr.as_ptr()
    }
}

impl<T: Copy> Drop for AVec<T> {
    fn drop(&mut self) {
        if self.cap > 0 {
            unsafe {
                dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.cap));
            }
        }
    }
}

impl<T: Copy> Default for AVec<T> {
    fn default() -> AVec<T> {
        AVec::new()
    }
}

impl<T: Copy> Clone for AVec<T> {
    fn clone(&self) -> AVec<T> {
        let mut v = AVec::new();
        if self.len > 0 {
            // resize allocates (aligned) then we overwrite the fill
            v.resize(self.len, self[0]);
            v.copy_from_slice(self);
        }
        v
    }
}

impl<T: Copy> Deref for AVec<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl<T: Copy> DerefMut for AVec<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        unsafe {
            std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len)
        }
    }
}

impl<T: Copy + std::fmt::Debug> std::fmt::Debug for AVec<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_list().entries(self.iter()).finish()
    }
}

impl<T: Copy + PartialEq> PartialEq for AVec<T> {
    fn eq(&self, other: &AVec<T>) -> bool {
        self[..] == other[..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn aligned<T: Copy>(v: &AVec<T>) -> bool {
        v.as_ptr() as usize % ALIGN == 0
    }

    #[test]
    fn resize_grows_fills_and_truncates() {
        let mut v: AVec<i32> = AVec::new();
        assert!(v.is_empty());
        v.resize(5, 7);
        assert_eq!(&v[..], &[7; 5]);
        v[2] = -1;
        // growth preserves the prefix and fills only the new tail
        v.resize(9, 3);
        assert_eq!(&v[..], &[7, 7, -1, 7, 7, 3, 3, 3, 3]);
        // shrink then regrow: the [3..5) slots are re-filled, the
        // surviving prefix is untouched
        v.resize(3, 0);
        v.resize(6, 9);
        assert_eq!(&v[..], &[7, 7, -1, 9, 9, 9]);
    }

    #[test]
    fn allocation_is_64_byte_aligned_through_growth() {
        let mut v: AVec<u8> = AVec::new();
        for n in [1usize, 63, 64, 65, 4096, 70_000] {
            v.resize(n, 0xAB);
            assert!(aligned(&v), "misaligned at len {n}");
        }
        let c = v.clone();
        assert!(aligned(&c), "clone lost alignment");
        assert_eq!(c, v);
    }

    #[test]
    fn wide_elements_stay_aligned() {
        let mut v: AVec<i64> = AVec::new();
        v.resize(1000, -5);
        assert!(aligned(&v));
        assert_eq!(v.iter().sum::<i64>(), -5000);
    }
}
