//! Small statistics helpers shared by the bench harness and experiments.

/// Summary of a sample of timings or errors.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: s[0],
            p50: percentile_sorted(&s, 50.0),
            p95: percentile_sorted(&s, 95.0),
            max: s[n - 1],
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Per-channel mean over a (N, C, ...) layout given flat data.
pub fn channel_means(data: &[f32], n: usize, c: usize, spatial: usize) -> Vec<f32> {
    let mut out = vec![0f64; c];
    let stride = c * spatial;
    for i in 0..n {
        for ch in 0..c {
            let base = i * stride + ch * spatial;
            let mut acc = 0f64;
            for s in 0..spatial {
                acc += data[base + s] as f64;
            }
            out[ch] += acc;
        }
    }
    let denom = (n * spatial) as f64;
    out.into_iter().map(|x| (x / denom) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let s = [0.0, 10.0];
        assert!((percentile_sorted(&s, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&s, 0.0), 0.0);
        assert_eq!(percentile_sorted(&s, 100.0), 10.0);
    }

    #[test]
    fn channel_means_layout() {
        // N=2, C=2, spatial=2
        let data = [1., 1., 2., 2., 3., 3., 4., 4.];
        let m = channel_means(&data, 2, 2, 2);
        assert_eq!(m, vec![2.0, 3.0]);
    }
}
