//! Fixed log-bucket (HDR-style) histograms.
//!
//! A [`Histogram`] spends a constant ~9 KiB of bucket counters no matter
//! how many samples it sees, so an always-on server can record forever
//! without the sample-trim cliff a raw `Vec<f64>` forces. Counters
//! (`count`, `sum`, `sum_sq`, `min`, `max`) are exact; percentile reads
//! return the **upper bound** of the bucket holding the nearest-rank
//! sample, so a reported quantile `q` satisfies
//! `exact <= q <= exact * 2^(1/SUB_PER_OCTAVE)` for in-range values —
//! with 32 sub-buckets per octave that is ≤ ~2.2% relative error
//! (property-tested against [`crate::util::stats::percentile_sorted`]).
//!
//! Bucket scheme: bucket 0 holds everything `<= MIN_VAL` (1 µs); bucket
//! `i >= 1` covers `(MIN_VAL·2^((i-1)/32), MIN_VAL·2^(i/32)]`; the last
//! bucket absorbs overflow (≥ ~19 h). Because cumulative bucket counts
//! only ever grow, two snapshots of the same stream subtract exactly —
//! [`Histogram::diff`] is what gives [`crate::serve::Metrics`] its
//! unbounded-lookback windows.

use crate::util::stats::Summary;

/// Sub-buckets per power of two: relative bucket width `2^(1/32) − 1`.
pub const SUB_PER_OCTAVE: usize = 32;
/// Lower edge of the first log bucket (1 µs for latencies; values at or
/// below it land in bucket 0).
pub const MIN_VAL: f64 = 1e-6;
/// Octaves covered above `MIN_VAL` before the overflow bucket
/// (`1e-6 · 2^36` ≈ 19 hours).
pub const OCTAVES: usize = 36;
const N_BUCKETS: usize = 1 + SUB_PER_OCTAVE * OCTAVES;

/// A fixed-size log-bucket histogram with exact counters.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            counts: vec![0; N_BUCKETS],
            count: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

/// Bucket index for a value (monotonic in `v`; NaN and `<= MIN_VAL`
/// both land in bucket 0).
fn bucket_of(v: f64) -> usize {
    if !(v > MIN_VAL) {
        return 0;
    }
    let idx = ((v / MIN_VAL).log2() * SUB_PER_OCTAVE as f64).ceil();
    (idx.max(1.0) as usize).min(N_BUCKETS - 1)
}

/// Upper bound of bucket `i` (`MIN_VAL` for bucket 0).
fn bucket_bound(i: usize) -> f64 {
    MIN_VAL * (i as f64 / SUB_PER_OCTAVE as f64).exp2()
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    pub fn record(&mut self, v: f64) {
        self.counts[bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.sum_sq += v * v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum / self.count as f64 }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.max }
    }

    /// Population standard deviation from the exact running moments.
    pub fn std(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let m = self.mean();
        (self.sum_sq / self.count as f64 - m * m).max(0.0).sqrt()
    }

    /// Nearest-rank percentile as a bucket upper bound, clamped into
    /// the exact `[min, max]` envelope. `0.0` when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank =
            ((p / 100.0 * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_bound(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// [`Summary`]-shaped view (p50/p95 are bucket bounds; the rest is
    /// exact). `None` when empty.
    pub fn summary(&self) -> Option<Summary> {
        if self.count == 0 {
            return None;
        }
        Some(Summary {
            n: self.count as usize,
            mean: self.mean(),
            std: self.std(),
            min: self.min,
            p50: self.percentile(50.0),
            p95: self.percentile(95.0),
            max: self.max,
        })
    }

    /// Fold another histogram in (exact: counters and buckets add).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.sum_sq += other.sum_sq;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// The samples recorded since `earlier`, where `earlier` is a prior
    /// snapshot (clone) of `self`'s stream — bucket counts and moments
    /// subtract exactly. The window's `min`/`max` are reconstructed from
    /// its outermost non-empty buckets (bound-accurate, not exact),
    /// clamped into the cumulative envelope.
    pub fn diff(&self, earlier: &Histogram) -> Histogram {
        let counts: Vec<u64> = self
            .counts
            .iter()
            .zip(&earlier.counts)
            .map(|(a, b)| a.saturating_sub(*b))
            .collect();
        let lo = counts.iter().position(|&c| c > 0);
        let hi = counts.iter().rposition(|&c| c > 0);
        let (min, max) = match (lo, hi) {
            (Some(l), Some(h)) => (
                if l == 0 { 0.0 } else { bucket_bound(l - 1) }
                    .max(self.min),
                bucket_bound(h).min(self.max),
            ),
            _ => (f64::INFINITY, f64::NEG_INFINITY),
        };
        Histogram {
            counts,
            count: self.count.saturating_sub(earlier.count),
            sum: self.sum - earlier.sum,
            sum_sq: self.sum_sq - earlier.sum_sq,
            min,
            max,
        }
    }

    /// Non-empty buckets as `(upper_bound, count)` pairs in value order.
    pub fn buckets(&self) -> Vec<(f64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (bucket_bound(i), c))
            .collect()
    }

    /// Cumulative `(le, count)` pairs for Prometheus `_bucket` lines:
    /// one entry per non-empty bucket, counts monotone non-decreasing.
    /// The `+Inf` bucket is implied by [`Histogram::count`].
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                cum += c;
                out.push((bucket_bound(i), cum));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::percentile_sorted;

    /// Worst-case relative error of a bucket-bound percentile.
    const REL: f64 = 0.023; // 2^(1/32) - 1 ≈ 0.0219, plus float slop

    fn assert_percentile_bounds(h: &Histogram, xs: &mut [f64]) {
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
            // nearest-rank oracle (the histogram's contract)
            let rank = ((p / 100.0 * xs.len() as f64).ceil() as usize)
                .clamp(1, xs.len());
            let exact = xs[rank - 1];
            let got = h.percentile(p);
            assert!(
                got >= exact * (1.0 - 1e-9),
                "p{p}: bound {got} below exact {exact}"
            );
            assert!(
                got <= exact * (1.0 + REL) + 1e-12,
                "p{p}: bound {got} too far above exact {exact}"
            );
        }
    }

    #[test]
    fn percentile_bounds_vs_exact_uniform_and_lognormal() {
        let mut rng = Rng::new(77);
        for trial in 0..8 {
            let n = 100 + trial * 531;
            let mut h = Histogram::new();
            let mut xs = Vec::with_capacity(n);
            for _ in 0..n {
                // latency-like spread: ~50 µs .. ~5 s
                let v = if trial % 2 == 0 {
                    rng.uniform(5e-5, 5.0) as f64
                } else {
                    // clamp above MIN_VAL: below it the bucket-bound
                    // contract intentionally degrades to "<= 1 µs"
                    (5e-4 * (rng.normal() as f64 * 1.5).exp())
                        .clamp(5e-6, 4.9)
                };
                h.record(v);
                xs.push(v);
            }
            assert_percentile_bounds(&h, &mut xs);
        }
    }

    #[test]
    fn moments_are_exact_and_interpolated_percentiles_bracketed() {
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64 * 1e-4).collect();
        let mut h = Histogram::new();
        for &x in &xs {
            h.record(x);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 0.05005).abs() < 1e-12);
        assert_eq!(h.min(), 1e-4);
        assert!((h.max() - 0.1).abs() < 1e-12);
        // the linear-interpolated oracle lies within one bucket too
        let p95 = percentile_sorted(&xs, 95.0);
        assert!(h.percentile(95.0) >= p95 * (1.0 - 1e-9));
        assert!(h.percentile(95.0) <= p95 * (1.0 + 2.0 * REL));
    }

    #[test]
    fn out_of_range_values_clamp_not_panic() {
        let mut h = Histogram::new();
        h.record(0.0);
        h.record(-1.0); // defensive: negative "latency"
        h.record(1e9); // overflow bucket
        assert_eq!(h.count(), 3);
        assert_eq!(h.max(), 1e9);
        assert_eq!(h.min(), -1.0);
        // percentiles stay inside the exact envelope
        let p = h.percentile(50.0);
        assert!((-1.0..=1e9).contains(&p));
    }

    #[test]
    fn diff_recovers_the_tail_window_exactly() {
        let mut h = Histogram::new();
        for i in 0..500 {
            h.record(0.001 + i as f64 * 1e-5);
        }
        let checkpoint = h.clone();
        let tail: Vec<f64> =
            (0..250).map(|i| 0.05 + i as f64 * 1e-4).collect();
        for &v in &tail {
            h.record(v);
        }
        let w = h.diff(&checkpoint);
        assert_eq!(w.count(), 250);
        let want_mean = tail.iter().sum::<f64>() / 250.0;
        assert!((w.mean() - want_mean).abs() < 1e-9);
        // window min/max are bucket-bound accurate
        assert!(w.min() <= tail[0] && w.min() >= tail[0] * (1.0 - REL));
        assert!(w.max() >= tail[249] * (1.0 - 1e-9));
        assert!(w.max() <= tail[249] * (1.0 + REL));
        let mut sorted = tail.clone();
        assert_percentile_bounds(&w, &mut sorted);
        // empty diff
        let none = h.diff(&h.clone());
        assert_eq!(none.count(), 0);
        assert!(none.summary().is_none());
    }

    #[test]
    fn merge_matches_recording_everything_in_one() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut all = Histogram::new();
        let mut rng = Rng::new(9);
        for i in 0..400 {
            let v = rng.uniform(1e-4, 2.0) as f64;
            if i % 2 == 0 { a.record(v) } else { b.record(v) }
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.percentile(95.0), all.percentile(95.0));
        assert!((a.sum() - all.sum()).abs() < 1e-9);
        assert_eq!(a.min(), all.min());
        assert_eq!(a.max(), all.max());
    }

    #[test]
    fn cumulative_buckets_are_monotone_and_total() {
        let mut h = Histogram::new();
        let mut rng = Rng::new(3);
        for _ in 0..300 {
            h.record(rng.uniform(1e-5, 0.5) as f64);
        }
        let cum = h.cumulative_buckets();
        assert!(!cum.is_empty());
        for w in cum.windows(2) {
            assert!(w[0].0 < w[1].0, "le bounds ascend");
            assert!(w[0].1 <= w[1].1, "counts monotone");
        }
        assert_eq!(cum.last().unwrap().1, h.count());
    }
}
