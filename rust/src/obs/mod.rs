//! Observability: bounded tracing, log-bucket histograms, and export
//! rendering — the runtime's own telemetry, with no external crates.
//!
//! Three pieces, each usable on its own:
//!
//! * [`trace`] — a lock-cheap, bounded ring-buffer event log. Subsystems
//!   (the autoscaler, the registry lifecycle, artifact loads, plan
//!   fallbacks) emit structured [`trace::Event`]s through a global
//!   buffer that costs one relaxed atomic load when disabled (the
//!   default). Enable with [`trace::set_enabled`] or `DFQ_TRACE=1`.
//! * [`hist`] — fixed log-bucket (HDR-style) [`hist::Histogram`]s:
//!   constant memory regardless of sample count, exact counters/sums,
//!   and percentile reads that are bucket upper bounds (≤ ~2.2%
//!   relative error). [`crate::serve::Metrics`] is built on these, which
//!   is what lets it drop the old 16 384-sample trim cliff.
//! * [`export`] — Prometheus-style text exposition and one-line JSON
//!   rendering, plus [`export::check_exposition`], the line-format
//!   checker the tests (and CI) run over real exposition output.
//!
//! The per-op runtime profile ([`crate::nn::qengine::RunProfile`]) lives
//! with the plan executor in [`crate::nn::qengine::plan`]; this module
//! only renders it. See `docs/OBSERVABILITY.md` for the full picture.

pub mod export;
pub mod hist;
pub mod trace;

pub use export::{check_exposition, Exposition};
pub use hist::Histogram;
pub use trace::{Event, Severity, SpanGuard, TraceBuf};
