//! Metrics export rendering: Prometheus-style text exposition and
//! one-line JSON, plus the line-format checker the tests run over real
//! output.
//!
//! The exposition dialect is the Prometheus text format restricted to
//! what this crate emits: `# HELP` / `# TYPE` comments and sample lines
//! `name{label="value",...} float`. Histograms render the conventional
//! triplet — `name_bucket{le="..."}` (cumulative, closed by `le="+Inf"`),
//! `name_sum`, `name_count`. No timestamps, no exemplars.

use super::hist::Histogram;

/// Incremental builder for a text exposition document.
#[derive(Default)]
pub struct Exposition {
    out: String,
}

impl Exposition {
    pub fn new() -> Exposition {
        Exposition::default()
    }

    pub fn counter(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        value: f64,
    ) {
        self.header(name, help, "counter");
        self.sample(name, labels, value);
    }

    pub fn gauge(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        value: f64,
    ) {
        self.header(name, help, "gauge");
        self.sample(name, labels, value);
    }

    /// One `# TYPE` header, many labelled samples of the same gauge
    /// (e.g. a quantile family).
    pub fn gauge_set(
        &mut self,
        name: &str,
        help: &str,
        rows: &[(&[(&str, &str)], f64)],
    ) {
        self.header(name, help, "gauge");
        for (labels, value) in rows {
            self.sample(name, labels, *value);
        }
    }

    /// Render a [`Histogram`] as `_bucket`/`_sum`/`_count` lines. Only
    /// non-empty buckets get a line (the cumulative counts are still
    /// correct); `le="+Inf"` always closes the series.
    pub fn histogram(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        h: &Histogram,
    ) {
        self.header(name, help, "histogram");
        let bucket = format!("{name}_bucket");
        for (le, cum) in h.cumulative_buckets() {
            let le = format!("{le:.9}");
            let mut ls: Vec<(&str, &str)> = labels.to_vec();
            ls.push(("le", &le));
            self.sample(&bucket, &ls, cum as f64);
        }
        let mut ls: Vec<(&str, &str)> = labels.to_vec();
        ls.push(("le", "+Inf"));
        self.sample(&bucket, &ls, h.count() as f64);
        self.sample(&format!("{name}_sum"), labels, h.sum());
        self.sample(&format!("{name}_count"), labels, h.count() as f64);
    }

    fn header(&mut self, name: &str, help: &str, kind: &str) {
        self.out.push_str(&format!("# HELP {name} {help}\n"));
        self.out.push_str(&format!("# TYPE {name} {kind}\n"));
    }

    fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(&format!("{k}=\"{}\"", escape_label(v)));
            }
            self.out.push('}');
        }
        self.out.push_str(&format!(" {value}\n"));
    }

    pub fn finish(self) -> String {
        self.out
    }
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Escape a string for embedding in a JSON document.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out
}

fn valid_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Validate a text exposition document line by line. Returns the first
/// offence as `Err("line N: why")`. This is deliberately a *format*
/// checker (names, label quoting, float values, known TYPE kinds), not
/// a semantic one — it is what the CI test asserts over live
/// [`crate::serve::Metrics::exposition`] output.
pub fn check_exposition(text: &str) -> Result<(), String> {
    for (i, line) in text.lines().enumerate() {
        let at = |why: &str| Err(format!("line {}: {why} [{line}]", i + 1));
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            let mut parts = rest.splitn(3, ' ');
            let (kw, name) = (parts.next().unwrap_or(""), parts.next());
            match kw {
                "HELP" => match name {
                    Some(n) if valid_name(n) => continue,
                    _ => return at("HELP without a valid metric name"),
                },
                "TYPE" => {
                    let Some(n) = name else {
                        return at("TYPE without a metric name");
                    };
                    if !valid_name(n) {
                        return at("TYPE with an invalid metric name");
                    }
                    match parts.next() {
                        Some("counter" | "gauge" | "histogram" | "summary"
                        | "untyped") => continue,
                        _ => return at("TYPE with an unknown kind"),
                    }
                }
                _ => return at("unknown comment keyword"),
            }
        }
        if line.starts_with('#') {
            return at("comment must start with '# '");
        }
        // sample line: name[{labels}] value
        let (head, value) = match line.rsplit_once(' ') {
            Some(x) => x,
            None => return at("sample line has no value"),
        };
        if value.parse::<f64>().is_err()
            && !matches!(value, "+Inf" | "-Inf" | "NaN")
        {
            return at("value is not a float");
        }
        let name = match head.split_once('{') {
            None => head,
            Some((n, rest)) => {
                let Some(body) = rest.strip_suffix('}') else {
                    return at("unterminated label set");
                };
                if !check_labels(body) {
                    return at("malformed label set");
                }
                n
            }
        };
        if !valid_name(name) {
            return at("invalid metric name");
        }
    }
    Ok(())
}

/// `k="v",k2="v2"` with `\\`, `\"`, `\n` escapes inside values.
fn check_labels(body: &str) -> bool {
    let mut chars = body.chars().peekable();
    loop {
        // label name
        let mut name = String::new();
        while let Some(&c) = chars.peek() {
            if c.is_ascii_alphanumeric() || c == '_' {
                name.push(c);
                chars.next();
            } else {
                break;
            }
        }
        if name.is_empty() || chars.next() != Some('=') {
            return false;
        }
        if chars.next() != Some('"') {
            return false;
        }
        // quoted value with escapes
        loop {
            match chars.next() {
                Some('\\') => {
                    if !matches!(chars.next(), Some('\\' | '"' | 'n')) {
                        return false;
                    }
                }
                Some('"') => break,
                Some(_) => {}
                None => return false,
            }
        }
        match chars.next() {
            None => return true,
            Some(',') => continue,
            Some(_) => return false,
        }
    }
}

/// Check a one-record-per-line JSON stream: every non-empty line must
/// be a braced object with balanced quotes/braces. (Shallow by design —
/// the bench/CI records are flat objects.)
pub fn check_json_lines(text: &str) -> Result<(), String> {
    for (i, line) in text.lines().enumerate() {
        if line.is_empty() {
            continue;
        }
        if !line.starts_with('{') || !line.ends_with('}') {
            return Err(format!("line {}: not a JSON object", i + 1));
        }
        let mut depth = 0i32;
        let mut in_str = false;
        let mut esc = false;
        for c in line.chars() {
            if esc {
                esc = false;
                continue;
            }
            match c {
                '\\' if in_str => esc = true,
                '"' => in_str = !in_str,
                '{' | '[' if !in_str => depth += 1,
                '}' | ']' if !in_str => depth -= 1,
                _ => {}
            }
        }
        if depth != 0 || in_str {
            return Err(format!("line {}: unbalanced object", i + 1));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exposition_roundtrips_through_the_checker() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-4);
        }
        let mut e = Exposition::new();
        e.counter(
            "dfq_requests_completed",
            "Requests completed.",
            &[("model", "alpha"), ("variant", "int8")],
            100.0,
        );
        e.gauge("dfq_queue_depth", "Queue depth.", &[], 3.0);
        e.histogram(
            "dfq_latency_seconds",
            "Request latency.",
            &[("model", "alpha")],
            &h,
        );
        let text = e.finish();
        check_exposition(&text).unwrap();
        assert!(text.contains("# TYPE dfq_latency_seconds histogram"));
        assert!(text.contains("le=\"+Inf\"} 100"));
        assert!(text.contains("dfq_latency_seconds_count{model=\"alpha\"} 100"));
    }

    #[test]
    fn checker_rejects_malformed_lines() {
        assert!(check_exposition("dfq_ok 1.5\n").is_ok());
        assert!(check_exposition("dfq_ok{a=\"b\"} +Inf\n").is_ok());
        for bad in [
            "no_value\n",
            "1leading_digit 2\n",
            "dfq{unterminated=\"x\" 1\n",
            "dfq{=\"x\"} 1\n",
            "dfq{a=unquoted} 1\n",
            "dfq_ok not_a_float\n",
            "# TYPE dfq_ok tachometer\n",
            "#bad comment\n",
        ] {
            assert!(check_exposition(bad).is_err(), "accepted: {bad:?}");
        }
    }

    #[test]
    fn label_values_escape_cleanly() {
        let mut e = Exposition::new();
        e.gauge("dfq_g", "g", &[("path", "a\\b \"q\"\nend")], 1.0);
        check_exposition(&e.finish()).unwrap();
    }

    #[test]
    fn json_escape_and_line_checker() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert!(check_json_lines("{\"a\":1}\n{\"b\":\"x}\"}\n").is_ok());
        assert!(check_json_lines("{\"a\":1\n").is_err());
        assert!(check_json_lines("plain text\n").is_err());
    }
}
