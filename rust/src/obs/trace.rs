//! Bounded ring-buffer event tracing.
//!
//! A [`TraceBuf`] keeps the last `capacity` [`Event`]s and an exact
//! count of everything it overwrote — an always-on server can emit
//! forever in constant memory, and a reader always knows how much
//! history it missed. Emission is **off by default**: every emit path
//! starts with one relaxed atomic load, and the [`emit_with`] /
//! [`span`] forms do not even build their message (no formatting, no
//! allocation) when tracing is disabled, so instrumented hot paths cost
//! nothing until someone turns the buffer on ([`set_enabled`] or
//! `DFQ_TRACE=1` in the environment).
//!
//! Producers in this crate and their scopes:
//!
//! | scope       | emitted from                                        |
//! |-------------|-----------------------------------------------------|
//! | `autoscale` | every autoscaler transition (tick, from, to, reason)|
//! | `registry`  | reload / evict / poll / lazy-load / cap eviction    |
//! | `artifact`  | artifact open (mmap vs copy, compressed sections)   |
//! | `plan`      | plan compilation summary incl. f32 fallbacks        |
//! | `serve`     | server lifecycle (start, drain), admission sheds    |

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Event importance, ordered `Debug < Info < Warn < Error`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Debug,
    Info,
    Warn,
    Error,
}

impl Severity {
    pub fn as_str(&self) -> &'static str {
        match self {
            Severity::Debug => "DEBUG",
            Severity::Info => "INFO",
            Severity::Warn => "WARN",
            Severity::Error => "ERROR",
        }
    }
}

/// One traced occurrence: position in the stream (`seq`), time since
/// the buffer was created (`ts`), a static `scope`, a message, and
/// structured key/value pairs.
#[derive(Debug, Clone)]
pub struct Event {
    /// 0-based position in the emission stream (survives wraparound:
    /// the ring holds a contiguous tail of sequence numbers).
    pub seq: u64,
    pub ts: Duration,
    pub severity: Severity,
    pub scope: &'static str,
    pub msg: String,
    pub kv: Vec<(&'static str, String)>,
}

impl Event {
    /// Single-line rendering: `[12.345s] INFO  registry reload model=a`.
    pub fn line(&self) -> String {
        let mut s = format!(
            "[{:9.3}s] {:<5} {} {}",
            self.ts.as_secs_f64(),
            self.severity.as_str(),
            self.scope,
            self.msg
        );
        for (k, v) in &self.kv {
            s.push_str(&format!(" {k}={v}"));
        }
        s
    }
}

struct State {
    ring: Vec<Event>,
    /// Next slot to write (ring\[head\] is the oldest once full).
    head: usize,
    seq: u64,
    dropped: u64,
}

/// A bounded, thread-safe event ring. One global instance serves the
/// whole crate ([`global`]); tests build their own.
pub struct TraceBuf {
    enabled: AtomicBool,
    cap: usize,
    start: Instant,
    state: Mutex<State>,
}

impl TraceBuf {
    pub fn new(capacity: usize) -> TraceBuf {
        TraceBuf {
            enabled: AtomicBool::new(false),
            cap: capacity.max(1),
            start: Instant::now(),
            state: Mutex::new(State {
                ring: Vec::new(),
                head: 0,
                seq: 0,
                dropped: 0,
            }),
        }
    }

    /// One relaxed load — the entire cost of a disabled emit site.
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn emit(
        &self,
        severity: Severity,
        scope: &'static str,
        msg: impl Into<String>,
        kv: Vec<(&'static str, String)>,
    ) {
        if !self.enabled() {
            return;
        }
        self.push(severity, scope, msg.into(), kv);
    }

    /// Emit with a lazily-built payload: `f` runs only when enabled.
    pub fn emit_with<F>(&self, severity: Severity, scope: &'static str, f: F)
    where
        F: FnOnce() -> (String, Vec<(&'static str, String)>),
    {
        if !self.enabled() {
            return;
        }
        let (msg, kv) = f();
        self.push(severity, scope, msg, kv);
    }

    fn push(
        &self,
        severity: Severity,
        scope: &'static str,
        msg: String,
        kv: Vec<(&'static str, String)>,
    ) {
        let ts = self.start.elapsed();
        let mut s = self.state.lock().unwrap();
        let seq = s.seq;
        s.seq += 1;
        let ev = Event { seq, ts, severity, scope, msg, kv };
        if s.ring.len() < self.cap {
            s.ring.push(ev);
        } else {
            let head = s.head;
            s.ring[head] = ev;
            s.head = (head + 1) % self.cap;
            s.dropped += 1;
        }
    }

    /// Time a region: the guard emits a `Debug` event with the elapsed
    /// seconds on drop. Free when disabled (no clock read, no event).
    pub fn span(
        &self,
        scope: &'static str,
        name: &'static str,
    ) -> SpanGuard<'_> {
        SpanGuard {
            buf: self,
            scope,
            name,
            start: self.enabled().then(Instant::now),
        }
    }

    /// The retained events, oldest first (a snapshot; the ring keeps
    /// them).
    pub fn events(&self) -> Vec<Event> {
        let s = self.state.lock().unwrap();
        let mut out = Vec::with_capacity(s.ring.len());
        out.extend_from_slice(&s.ring[s.head..]);
        out.extend_from_slice(&s.ring[..s.head]);
        out
    }

    /// Take and clear the retained events (drop/seq counters persist).
    pub fn drain(&self) -> Vec<Event> {
        let mut s = self.state.lock().unwrap();
        let head = s.head;
        let mut tail = s.ring.split_off(head);
        tail.append(&mut s.ring);
        s.ring = Vec::new();
        s.head = 0;
        tail
    }

    /// Events overwritten because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.state.lock().unwrap().dropped
    }

    /// Total events ever emitted (= next sequence number).
    pub fn emitted(&self) -> u64 {
        self.state.lock().unwrap().seq
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&self) {
        let mut s = self.state.lock().unwrap();
        s.ring.clear();
        s.head = 0;
    }
}

/// Default capacity of the process-global buffer.
pub const GLOBAL_CAPACITY: usize = 1024;

/// The process-global trace buffer. First use decides the initial
/// enable state from `DFQ_TRACE` (any non-empty value other than `0`).
pub fn global() -> &'static TraceBuf {
    static GLOBAL: OnceLock<TraceBuf> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let buf = TraceBuf::new(GLOBAL_CAPACITY);
        buf.set_enabled(matches!(
            std::env::var("DFQ_TRACE"), Ok(v) if !v.is_empty() && v != "0"
        ));
        buf
    })
}

/// Is the global buffer recording? (One relaxed atomic load.)
pub fn enabled() -> bool {
    global().enabled()
}

pub fn set_enabled(on: bool) {
    global().set_enabled(on)
}

/// Emit to the global buffer (no-op when disabled).
pub fn emit(
    severity: Severity,
    scope: &'static str,
    msg: impl Into<String>,
    kv: Vec<(&'static str, String)>,
) {
    global().emit(severity, scope, msg, kv)
}

/// Lazily-built emit to the global buffer.
pub fn emit_with<F>(severity: Severity, scope: &'static str, f: F)
where
    F: FnOnce() -> (String, Vec<(&'static str, String)>),
{
    global().emit_with(severity, scope, f)
}

/// Span guard on the global buffer.
pub fn span(scope: &'static str, name: &'static str) -> SpanGuard<'static> {
    global().span(scope, name)
}

/// RAII timing guard from [`TraceBuf::span`].
pub struct SpanGuard<'a> {
    buf: &'a TraceBuf,
    scope: &'static str,
    name: &'static str,
    start: Option<Instant>,
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            let secs = t0.elapsed().as_secs_f64();
            self.buf.emit(
                Severity::Debug,
                self.scope,
                self.name,
                vec![("secs", format!("{secs:.6}"))],
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn disabled_buffer_records_nothing() {
        let b = TraceBuf::new(8);
        b.emit(Severity::Info, "t", "dropped on the floor", vec![]);
        let mut ran = false;
        b.emit_with(Severity::Info, "t", || {
            ran = true;
            ("never built".into(), vec![])
        });
        drop(b.span("t", "no-op"));
        assert!(!ran, "payload closure must not run when disabled");
        assert_eq!(b.len(), 0);
        assert_eq!(b.emitted(), 0);
    }

    #[test]
    fn ring_wraps_and_keeps_the_newest_tail() {
        let b = TraceBuf::new(8);
        b.set_enabled(true);
        for i in 0..20 {
            b.emit(Severity::Info, "wrap", format!("e{i}"), vec![]);
        }
        assert_eq!(b.len(), 8);
        assert_eq!(b.dropped(), 12);
        assert_eq!(b.emitted(), 20);
        let evs = b.events();
        let seqs: Vec<u64> = evs.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, (12..20).collect::<Vec<u64>>());
        assert_eq!(evs[0].msg, "e12");
        assert_eq!(evs.last().unwrap().msg, "e19");
        // drain empties the ring but the stream position survives
        let drained = b.drain();
        assert_eq!(drained.len(), 8);
        assert_eq!(b.len(), 0);
        b.emit(Severity::Info, "wrap", "after", vec![]);
        assert_eq!(b.events()[0].seq, 20);
    }

    #[test]
    fn concurrent_writers_lose_nothing_from_the_counters() {
        let b = Arc::new(TraceBuf::new(64));
        b.set_enabled(true);
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let b = Arc::clone(&b);
                std::thread::spawn(move || {
                    for i in 0..500 {
                        b.emit(
                            Severity::Debug,
                            "mt",
                            format!("t{t}:{i}"),
                            vec![("i", i.to_string())],
                        );
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(b.emitted(), 2000);
        assert_eq!(b.len(), 64);
        assert_eq!(b.dropped(), 2000 - 64);
        // retained tail is the last 64 sequence numbers, in order
        let seqs: Vec<u64> = b.events().iter().map(|e| e.seq).collect();
        assert_eq!(seqs, (2000 - 64..2000).collect::<Vec<u64>>());
    }

    #[test]
    fn spans_emit_elapsed_seconds() {
        let b = TraceBuf::new(8);
        b.set_enabled(true);
        {
            let _g = b.span("test", "region");
        }
        let evs = b.events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].msg, "region");
        assert_eq!(evs[0].kv[0].0, "secs");
        assert!(evs[0].kv[0].1.parse::<f64>().unwrap() >= 0.0);
        assert!(evs[0].line().contains("region"));
    }
}
