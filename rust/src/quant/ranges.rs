//! Data-free activation quantisation ranges (paper §5 experimental
//! setup): per-channel β ± n·γ from the propagated BatchNorm Gaussians,
//! reduced per tensor, min clipped at 0 after ReLU. One [`SiteCfg`] row
//! per activation site of the executable contract.

use anyhow::Result;

use std::collections::HashMap;

use crate::graph::stats::{propagate, site_range, TensorStats};
use crate::graph::{Model, Op, Site};
use crate::nn::{QuantCfg, SiteCfg};

use super::{params_for_range, QParams};

/// Number of standard deviations for activation ranges (paper: n = 6,
/// "a wide range of n can be used without significant difference").
pub const DEFAULT_N_SIGMA: f32 = 6.0;

/// Build the activation quantisation config for a prepared model.
///
/// `bits == 0` returns the FP32 passthrough (clip bounds only) — the
/// same executable then runs un-quantised activations.
pub fn activation_qcfg(
    model: &Model,
    bits: u32,
    symmetric: bool,
    n_sigma: f32,
) -> Result<QuantCfg> {
    if bits == 0 {
        return Ok(QuantCfg::fp32(model));
    }
    let stats = propagate(model)?;
    activation_qcfg_with(model, &stats, bits, symmetric, n_sigma)
}

/// [`activation_qcfg`] over precomputed node statistics — callers that
/// build several grid families (site rows + pre-activation grids)
/// propagate once and share the map.
pub fn activation_qcfg_with(
    model: &Model,
    stats: &HashMap<usize, TensorStats>,
    bits: u32,
    symmetric: bool,
    n_sigma: f32,
) -> Result<QuantCfg> {
    if bits == 0 {
        return Ok(QuantCfg::fp32(model));
    }
    let mut rows = Vec::new();
    for site in model.act_sites() {
        let row = match site {
            Site::Input => {
                // images are normalised to [0, 1]
                let p = params_for_range(0.0, 1.0, bits, symmetric);
                SiteCfg {
                    scale: p.scale,
                    zero_point: p.zero_point,
                    n_levels: p.n_levels,
                    clip_hi: f32::INFINITY,
                }
            }
            Site::Act { node, kind } => {
                // range of the *pre-activation* Gaussian, min clipped to
                // 0 (ReLU), max clipped by the activation bound.
                let input = model.node(node).inputs[0];
                let st = &stats[&input];
                let mut lo = f32::INFINITY;
                let mut hi = f32::NEG_INFINITY;
                for c in 0..st.mean.len() {
                    lo = lo.min(st.mean[c] - n_sigma * st.std[c]);
                    hi = hi.max(st.mean[c] + n_sigma * st.std[c]);
                }
                lo = lo.max(0.0);
                hi = hi.min(kind.clip_hi()).max(lo + 1e-6);
                let p = params_for_range(lo, hi, bits, symmetric);
                SiteCfg {
                    scale: p.scale,
                    zero_point: p.zero_point,
                    n_levels: p.n_levels,
                    clip_hi: kind.clip_hi(),
                }
            }
            Site::Add { node } | Site::Concat { node } => {
                // add: β ± n·γ of the summed Gaussian; concat: the same
                // reduction over the concatenated channel stats (the
                // shared grid every branch requantises onto)
                let st = &stats[&node];
                let mut lo = f32::INFINITY;
                let mut hi = f32::NEG_INFINITY;
                for c in 0..st.mean.len() {
                    lo = lo.min(st.mean[c] - n_sigma * st.std[c]);
                    hi = hi.max(st.mean[c] + n_sigma * st.std[c]);
                }
                let p = params_for_range(lo, hi.max(lo + 1e-6), bits, symmetric);
                SiteCfg {
                    scale: p.scale,
                    zero_point: p.zero_point,
                    n_levels: p.n_levels,
                    clip_hi: f32::INFINITY,
                }
            }
        };
        rows.push(row);
    }
    Ok(QuantCfg { rows })
}

/// Data-free *pre-activation* grids, one per conv node: per-channel
/// β ± n·γ reduced per tensor, with **no** ReLU clipping (residual
/// branches carry signed pre-activation values). The integer engine
/// requantises un-fused conv outputs — residual branches feeding adds —
/// onto these grids instead of falling back to f32 (see
/// `nn::qengine::AuxGrids`). `bits == 0` yields no grids (FP32 eval).
pub fn preact_qparams(
    model: &Model,
    bits: u32,
    symmetric: bool,
    n_sigma: f32,
) -> Result<Vec<(usize, QParams)>> {
    if bits == 0 {
        return Ok(Vec::new());
    }
    let stats = propagate(model)?;
    Ok(preact_qparams_with(model, &stats, bits, symmetric, n_sigma))
}

/// [`preact_qparams`] over precomputed node statistics.
pub fn preact_qparams_with(
    model: &Model,
    stats: &HashMap<usize, TensorStats>,
    bits: u32,
    symmetric: bool,
    n_sigma: f32,
) -> Vec<(usize, QParams)> {
    if bits == 0 {
        return Vec::new();
    }
    let mut out = Vec::new();
    for n in &model.nodes {
        if !matches!(n.op, Op::Conv { .. } | Op::ConvT2d { .. }) {
            continue;
        }
        let (lo, hi) = site_range(&stats[&n.id], n_sigma, None);
        out.push((n.id, params_for_range(lo, hi, bits, symmetric)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfq::bn_fold;
    use crate::dfq::testutil::two_layer_model;

    #[test]
    fn builds_rows_per_site() {
        let m = bn_fold::fold(&two_layer_model(61, true)).unwrap();
        let cfg = activation_qcfg(&m, 8, false, 6.0).unwrap();
        assert_eq!(cfg.rows.len(), m.act_sites().len());
        for r in &cfg.rows {
            assert!(r.scale > 0.0);
            assert_eq!(r.n_levels, 256.0);
        }
    }

    #[test]
    fn bits_zero_is_fp32() {
        let m = bn_fold::fold(&two_layer_model(62, true)).unwrap();
        let cfg = activation_qcfg(&m, 0, false, 6.0).unwrap();
        assert!(cfg.rows.iter().all(|r| r.n_levels == 0.0));
    }

    #[test]
    fn preact_grids_cover_every_conv() {
        let m = bn_fold::fold(&two_layer_model(64, true)).unwrap();
        let grids = preact_qparams(&m, 8, false, 6.0).unwrap();
        let convs = m
            .layers()
            .iter()
            .filter(|n| {
                matches!(n.op, Op::Conv { .. } | Op::ConvT2d { .. })
            })
            .count();
        assert_eq!(grids.len(), convs);
        for (_, p) in &grids {
            assert!(p.scale > 0.0 && p.zero_point.fract() == 0.0);
            assert_eq!(p.n_levels, 256.0);
        }
        assert!(preact_qparams(&m, 0, false, 6.0).unwrap().is_empty());
    }

    #[test]
    fn flat_layout_is_s_by_4() {
        let m = bn_fold::fold(&two_layer_model(63, true)).unwrap();
        let cfg = activation_qcfg(&m, 8, false, 6.0).unwrap();
        let flat = cfg.to_flat();
        assert_eq!(flat.len(), cfg.rows.len() * 4);
        assert!(flat.iter().all(|x| x.is_finite()));
    }
}
