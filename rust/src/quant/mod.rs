//! Fixed-point quantisation core: schemes, grid parameters, fake-quant.
//!
//! Follows the paper's §5 setup: asymmetric per-tensor quantisation by
//! default, ranges = min/max of the weight tensor; symmetric and
//! per-channel variants for the appendix-E comparisons. The integer grid
//! is expressed as `q ∈ [0, n_levels-1]` with a float zero-point so the
//! same `(scale, zp, n)` triple drives the Rust engine, the PJRT
//! executable argument, and the Pallas kernel epilogue.

pub mod ranges;

use anyhow::{bail, Result};

use crate::tensor::{QTensor, Tensor};

/// Bit-widths the fixed-point grid supports. `1u64 << bits` is only
/// meaningful below 32 (beyond that the `n - 1` arithmetic drowns in f32
/// rounding and the grid silently degenerates), and a 0-bit grid has no
/// levels at all — both are programming errors, rejected loudly.
pub fn check_bits(bits: u32) {
    assert!(
        (1..32).contains(&bits),
        "quantisation bit-width must be in 1..=31, got {bits} \
         (bits == 0 has no levels; bits >= 32 overflows the grid)"
    );
}

/// A quantisation scheme for weights or activations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QScheme {
    pub bits: u32,
    pub symmetric: bool,
    pub per_channel: bool,
}

impl QScheme {
    pub fn int8_asymmetric() -> QScheme {
        QScheme { bits: 8, symmetric: false, per_channel: false }
    }

    pub fn int8_symmetric() -> QScheme {
        QScheme { bits: 8, symmetric: true, per_channel: false }
    }

    pub fn per_channel(bits: u32) -> QScheme {
        QScheme { bits, symmetric: false, per_channel: true }
    }

    pub fn with_bits(self, bits: u32) -> QScheme {
        QScheme { bits, ..self }
    }

    pub fn n_levels(&self) -> f32 {
        check_bits(self.bits);
        (1u64 << self.bits) as f32
    }
}

/// Affine grid parameters (see [`crate::nn::ops::fake_quant_scalar`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QParams {
    pub scale: f32,
    pub zero_point: f32,
    pub n_levels: f32,
}

impl QParams {
    /// Identity (no quantisation).
    pub fn identity() -> QParams {
        QParams { scale: 1.0, zero_point: 0.0, n_levels: 0.0 }
    }
}

/// Grid parameters covering `[lo, hi]`.
///
/// * asymmetric: the grid spans [min(lo,0), max(hi,0)] (zero must be
///   exactly representable — standard for zero-padded convolutions).
/// * symmetric: the grid is centred, scale set by max(|lo|, |hi|).
pub fn params_for_range(lo: f32, hi: f32, bits: u32, symmetric: bool) -> QParams {
    check_bits(bits);
    let n = (1u64 << bits) as f32;
    if symmetric {
        let a = lo.abs().max(hi.abs()).max(1e-12);
        let scale = a / (n / 2.0 - 1.0);
        QParams { scale, zero_point: n / 2.0, n_levels: n }
    } else {
        let lo = lo.min(0.0);
        let hi = hi.max(0.0).max(lo + 1e-12);
        let scale = (hi - lo) / (n - 1.0);
        let zero_point = (-lo / scale).round();
        QParams { scale, zero_point, n_levels: n }
    }
}

/// Fake-quantise a whole tensor with one grid (per-tensor).
pub fn fake_quant_tensor(t: &mut Tensor, p: &QParams) {
    crate::nn::ops::fake_quant(t, p.scale, p.zero_point, p.n_levels);
}

/// Grid(s) for a weight tensor under `scheme`: one per tensor, or one
/// per output channel. The single source of the range→grid rule shared
/// by [`quantize_weights`] and [`quantize_weights_retaining`] (the
/// fake-quant model and the retained integer codes must always come
/// from identical grids).
pub fn params_for_scheme(t: &Tensor, scheme: &QScheme) -> Vec<QParams> {
    if scheme.per_channel {
        t.channel_ranges()
            .into_iter()
            .map(|(lo, hi)| {
                params_for_range(lo, hi, scheme.bits, scheme.symmetric)
            })
            .collect()
    } else {
        vec![params_for_range(t.min(), t.max(), scheme.bits, scheme.symmetric)]
    }
}

/// Quantise a weight tensor in place per `scheme`; returns the grid(s)
/// used (one per tensor, or one per output channel).
pub fn quantize_weights(t: &mut Tensor, scheme: &QScheme) -> Vec<QParams> {
    let params = params_for_scheme(t, scheme);
    if scheme.per_channel {
        for (o, p) in params.iter().enumerate() {
            for x in t.out_channel_mut(o) {
                *x = crate::nn::ops::fake_quant_scalar(
                    *x, p.scale, p.zero_point, p.n_levels,
                );
            }
        }
    } else {
        fake_quant_tensor(t, &params[0]);
    }
    params
}

/// Like [`quantize_weights`], but *retains the integer grid codes* the
/// fake-quant image is computed from: fake-quantises `t` in place and
/// returns the grid(s) plus a signed-storage [`QTensor`] holding the
/// codes, so the integer engine never re-derives them. The written-back
/// f32 values are bit-identical to [`quantize_weights`]'s.
///
/// Requires `bits <= 8` (i8 storage); use [`quantize_weights`] for the
/// wide-grid appendix sweeps.
pub fn quantize_weights_retaining(
    t: &mut Tensor,
    scheme: &QScheme,
) -> Result<(Vec<QParams>, QTensor)> {
    check_bits(scheme.bits);
    if scheme.bits > 8 {
        bail!(
            "quantize_weights_retaining packs i8 codes; bits = {} > 8",
            scheme.bits
        );
    }
    let params = params_for_scheme(t, scheme);
    let codes = QTensor::quantize(t, &params, true)?;
    *t = codes.dequantize();
    Ok((params, codes))
}

/// Worst-case quantisation SNR proxy: the per-channel "precision" of
/// eq. 8 in the paper — channel range over tensor range.
pub fn channel_precision(t: &Tensor) -> Vec<f32> {
    let total = 2.0 * t.abs_max();
    if total == 0.0 {
        return vec![0.0; t.shape()[0]];
    }
    t.channel_ranges()
        .iter()
        .map(|(lo, hi)| (2.0 * lo.abs().max(hi.abs())) / total)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asymmetric_grid_contains_zero() {
        let p = params_for_range(0.5, 2.0, 8, false);
        // lo is pulled to 0; zero maps exactly to zp
        assert_eq!(p.zero_point, 0.0);
        let p = params_for_range(-1.0, 1.0, 8, false);
        let zero_back = (p.zero_point - p.zero_point) * p.scale;
        assert_eq!(zero_back, 0.0);
        assert!((p.scale - 2.0 / 255.0).abs() < 1e-7);
    }

    #[test]
    fn symmetric_grid() {
        let p = params_for_range(-3.0, 1.0, 8, true);
        assert_eq!(p.zero_point, 128.0);
        assert!((p.scale - 3.0 / 127.0).abs() < 1e-7);
    }

    #[test]
    fn quantize_roundtrip_error_bounded() {
        let mut t = Tensor::from_vec(
            (0..100).map(|i| (i as f32) / 25.0 - 2.0).collect(),
        );
        let orig = t.clone();
        let ps = quantize_weights(&mut t, &QScheme::int8_asymmetric());
        assert_eq!(ps.len(), 1);
        // max error <= scale/2
        assert!(t.max_abs_diff(&orig) <= ps[0].scale / 2.0 + 1e-7);
    }

    #[test]
    fn per_channel_tighter_than_per_tensor() {
        // channel 0 tiny, channel 1 huge: per-channel must quantise
        // channel 0 much more precisely.
        let data: Vec<f32> = (0..8)
            .map(|i| if i < 4 { 0.01 * i as f32 } else { 10.0 * i as f32 })
            .collect();
        let t = Tensor::new(&[2, 4], data);
        let mut pt = t.clone();
        let mut pc = t.clone();
        quantize_weights(&mut pt, &QScheme::int8_asymmetric());
        quantize_weights(&mut pc, &QScheme::per_channel(8));
        let err_pt: f32 = (0..4).map(|i| (pt.data()[i] - t.data()[i]).abs()).sum();
        let err_pc: f32 = (0..4).map(|i| (pc.data()[i] - t.data()[i]).abs()).sum();
        assert!(err_pc < err_pt / 10.0, "{err_pc} vs {err_pt}");
    }

    #[test]
    fn low_bit_grids() {
        for bits in [2, 4, 6, 8, 12, 16] {
            let p = params_for_range(-1.0, 1.0, bits, false);
            assert_eq!(p.n_levels, (1u64 << bits) as f32);
            assert!(p.scale > 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "bit-width must be in 1..=31")]
    fn zero_bits_rejected() {
        params_for_range(-1.0, 1.0, 0, false);
    }

    #[test]
    #[should_panic(expected = "bit-width must be in 1..=31")]
    fn huge_bits_rejected() {
        params_for_range(-1.0, 1.0, 32, false);
    }

    #[test]
    #[should_panic(expected = "bit-width must be in 1..=31")]
    fn n_levels_guards_bits() {
        let _ = QScheme::int8_asymmetric().with_bits(0).n_levels();
    }

    #[test]
    fn retaining_matches_in_place_quantisation() {
        let mut rng = crate::util::rng::Rng::new(31);
        for scheme in [
            QScheme::int8_asymmetric(),
            QScheme::int8_symmetric(),
            QScheme::per_channel(8),
            QScheme::int8_asymmetric().with_bits(4),
        ] {
            let t = Tensor::new(&[4, 3, 3, 3], rng.normal_vec(108, 0.7));
            let mut a = t.clone();
            let mut b = t.clone();
            let pa = quantize_weights(&mut a, &scheme);
            let (pb, codes) =
                quantize_weights_retaining(&mut b, &scheme).unwrap();
            assert_eq!(pa, pb);
            assert_eq!(a, b, "retaining path diverged for {scheme:?}");
            assert_eq!(codes.dequantize(), a);
        }
    }

    #[test]
    fn retaining_rejects_wide_grids() {
        let mut t = Tensor::from_vec(vec![0.0, 1.0]);
        let wide = QScheme::int8_asymmetric().with_bits(16);
        assert!(quantize_weights_retaining(&mut t, &wide).is_err());
    }

    #[test]
    fn precision_metric() {
        let t = Tensor::new(&[2, 2], vec![0.1, -0.1, 1.0, -1.0]);
        let p = channel_precision(&t);
        assert!((p[0] - 0.1).abs() < 1e-6);
        assert!((p[1] - 1.0).abs() < 1e-6);
    }
}
