//! `dfq` CLI — the L3 leader entrypoint.
//!
//! Subcommands (see `docs/CLI.md` for the full reference):
//!
//! ```text
//! table <1..8|all>      regenerate a paper table
//! fig <1|2|3|6>         regenerate a paper figure (CSV series)
//! quantize <arch> [...] run the DFQ pipeline, save the quantised model
//! compile <arch> [...]  run DFQ once, write a compiled .dfqm artifact
//! report <arch> [...]   run the instrumented pass pipeline, print the
//!                       per-pass diagnostics table (or JSON records)
//! profile <arch> [...]  run the int8 plan with per-op profiling and
//!                       print the time/bytes/kernel table (or JSON)
//! eval <arch> [...]     evaluate a model (fp32 / int8 / dfq variants)
//! serve <arch> [...]    start the batching server + synthetic load
//!                       (--autoscale steers f32 <-> int8 adaptively)
//! serve --models DIR    multi-model registry serving over artifacts
//!                       (--watch hot-swaps changed files, --max-resident
//!                       caps loaded models with LRU eviction)
//! inspect <arch|.dfqm>  model structure / compiled-artifact report
//! ```
//!
//! Hand-rolled argument parsing (no clap in the offline crate set).

use std::collections::HashMap;

use anyhow::{bail, Context as _, Result};

use dfq::dfq::{quantize_data_free, BiasCorrMode, DfqConfig, QuantizedModel};
use dfq::experiments;
use dfq::graph::Model;
use dfq::nn::QuantCfg;
use dfq::quant::QScheme;
use dfq::runtime::{Manifest, Runtime};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: dfq <command>\n\
         \n\
         commands:\n\
           table <1..8|all>            regenerate paper table(s)\n\
           fig <1|2|3|6>               regenerate paper figure CSV\n\
           quantize <arch> [--bits N] [--bc none|analytic|empirical]\n\
                    [--per-channel] [--symmetric] [--out FILE]\n\
           compile <arch> [--bits N] [--bc none|analytic|empirical]\n\
                   [--per-channel] [--symmetric] [--allow-fallback]\n\
                   [--compress]        store weight grid + plan compressed\n\
                   [-o|--out FILE]     write a compiled .dfqm artifact\n\
           report <arch|fixture> [--bits N] [--bc none|analytic] [--json]\n\
                  per-pass DFQ diagnostics (spread, CLE trace, BC |db|);\n\
                  fixtures: two_layer | resblock | inception |\n\
                            deeplab | ssd\n\
           profile <arch|fixture> [--runs N] [--json]\n\
                  per-op runtime profile of the int8 plan (wall time,\n\
                  activation bytes, GEMM calls per kernel flavour);\n\
                  --json fails loudly on any plan fallback\n\
           eval <arch> [--mode fp32|baseline|dfq] [--bits N] [--limit N]\n\
           serve <arch> [--requests N] [--rate R] [--batch N]\n\
                 [--backend pjrt|engine|qengine] [--autoscale]\n\
                 [--lanes N] [--admission-cap N] [--slo-mix F]\n\
                 [--seed N] [--metrics-dump FILE]\n\
                 --autoscale: steer f32 <-> int8 from live metrics,\n\
                 --lanes shards the server across N worker lanes,\n\
                 --admission-cap sheds over-cap submissions (typed),\n\
                 --slo-mix F routes fraction F as interactive class\n\
           serve --models DIR [--requests N] [--rate R] [--batch N]\n\
                 [--watch] [--max-resident N] [--no-mmap]\n\
                 [--lanes N] [--admission-cap N] [--slo-mix F]\n\
                 [--zipf S] [--diurnal-amp F] [--burst-mult F]\n\
                 [--seed N] [--metrics-dump FILE]\n\
                 multi-model registry over compiled artifacts;\n\
                 --watch hot-swaps changed .dfqm files mid-run,\n\
                 --max-resident caps loaded models (LRU eviction),\n\
                 --no-mmap copies artifacts instead of memory-mapping,\n\
                 --lanes N worker lanes per (model, variant),\n\
                 --admission-cap per-model in-flight cap (0 = off),\n\
                 --slo-mix interactive fraction of the generated load,\n\
                 --zipf Zipf popularity skew across models (0 = RR),\n\
                 --diurnal-amp sinusoidal rate modulation in [0,1),\n\
                 --burst-mult burst-window rate multiplier (1 = off),\n\
                 --seed fixes the whole arrival trace,\n\
                 --metrics-dump periodically rewrites FILE with a\n\
                 Prometheus-style text exposition of the live metrics\n\
           inspect <arch|artifact.dfqm>\n\
         \n\
         env: DFQ_ARTIFACTS (artifacts dir),\n\
              DFQ_BACKEND: serve=pjrt|engine|qengine, eval=pjrt|engine,\n\
              DFQ_EVAL_LIMIT, DFQ_RESULTS (results dir),\n\
              DFQ_NO_MMAP=1 (force copy loads everywhere),\n\
              DFQ_TRACE=1 (record runtime events in the trace ring)"
    );
    std::process::exit(2);
}

fn flags(rest: &[String]) -> (Vec<&String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut kv = HashMap::new();
    let mut i = 0;
    while i < rest.len() {
        let a = &rest[i];
        if a == "-o" {
            // short alias for --out
            i += 1;
            kv.insert(
                "out".to_string(),
                rest.get(i).cloned().unwrap_or_default(),
            );
        } else if let Some(name) = a.strip_prefix("--") {
            let boolean = matches!(
                name,
                "per-channel"
                    | "symmetric"
                    | "allow-fallback"
                    | "json"
                    | "autoscale"
                    | "watch"
                    | "compress"
                    | "no-mmap"
            );
            if boolean {
                kv.insert(name.to_string(), "true".to_string());
            } else {
                i += 1;
                kv.insert(
                    name.to_string(),
                    rest.get(i).cloned().unwrap_or_default(),
                );
            }
        } else {
            pos.push(a);
        }
        i += 1;
    }
    (pos, kv)
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else { usage() };
    let rest = &args[1..];
    match cmd.as_str() {
        "table" => {
            let id = rest.first().map(|s| s.as_str()).unwrap_or("all");
            experiments::run(id)?;
            Ok(())
        }
        "fig" => {
            let id = rest.first().map(|s| s.as_str()).unwrap_or("1");
            experiments::run(&format!("fig{id}"))?;
            Ok(())
        }
        "quantize" => cmd_quantize(rest),
        "compile" => cmd_compile(rest),
        "report" => cmd_report(rest),
        "profile" => cmd_profile(rest),
        "eval" => cmd_eval(rest),
        "serve" => cmd_serve(rest),
        "inspect" => cmd_inspect(rest),
        _ => usage(),
    }
}

fn parse_bc(s: &str) -> Result<BiasCorrMode> {
    Ok(match s {
        "none" => BiasCorrMode::None,
        "analytic" => BiasCorrMode::Analytic,
        "empirical" => BiasCorrMode::Empirical,
        _ => bail!("unknown bias-correction mode '{s}'"),
    })
}

/// Shared front half of `quantize` and `compile`: manifest + model
/// load, DFQ prepare (with log line), scheme/calibration from flags,
/// quantise. Returns the quantised model and the weight bit-width.
fn quantize_from_flags(
    arch: &str,
    kv: &HashMap<String, String>,
) -> Result<(QuantizedModel, u32)> {
    let bits: u32 = kv.get("bits").map(|s| s.parse()).transpose()?.unwrap_or(8);
    let bc = parse_bc(kv.get("bc").map(|s| s.as_str()).unwrap_or("analytic"))?;
    let manifest = Manifest::load(dfq::artifacts_dir())?;
    let entry = manifest.arch(arch)?;
    let model = Model::load(manifest.path(&entry.model))?;
    println!(
        "loaded {arch}: {} nodes, {} params",
        model.nodes.len(),
        model.param_count()
    );
    let (prep, report) =
        dfq::dfq::quantize_data_free_report(&model, &DfqConfig::default())?;
    println!(
        "DFQ prepare: {} ReLU6 replaced, {} CLE pairs ({} sweeps), \
         {} channels absorbed",
        prep.log.relu6_replaced,
        prep.log.cle_pairs,
        prep.log.cle_sweeps,
        prep.log.absorbed_channels
    );
    print!("{}", report.table());
    let scheme = QScheme {
        bits,
        symmetric: kv.contains_key("symmetric"),
        per_channel: kv.contains_key("per-channel"),
    };
    let calib = match bc {
        BiasCorrMode::Empirical => {
            let ds = dfq::graph::io::Dataset::load(
                manifest.dataset(&entry.task, "calib")?,
            )?;
            Some(ds.batch(0, ds.len().min(128)))
        }
        _ => None,
    };
    let q = prep.quantize(&scheme, bits, bc, calib.as_ref())?;
    Ok((q, bits))
}

fn cmd_quantize(rest: &[String]) -> Result<()> {
    let (pos, kv) = flags(rest);
    let arch = pos.first().context("missing <arch>")?.as_str();
    let (q, bits) = quantize_from_flags(arch, &kv)?;
    let out = kv
        .get("out")
        .cloned()
        .unwrap_or_else(|| format!("{arch}_int{bits}.dfqm"));
    q.model.save(&out)?;
    println!("saved quantised model to {out}");
    Ok(())
}

/// `dfq compile <arch>`: run the full DFQ pipeline once and snapshot the
/// resulting integer execution plan as a `.dfqm` compiled artifact
/// (served later via `dfq serve --models` with zero pipeline cost).
fn cmd_compile(rest: &[String]) -> Result<()> {
    let (pos, kv) = flags(rest);
    let arch = pos.first().context("missing <arch>")?.as_str();
    let (q, bits) = quantize_from_flags(arch, &kv)?;
    // compiled artifacts promise pure-int8 serving by default; an f32
    // fallback op is an error unless explicitly allowed
    let opts = dfq::nn::qengine::PlanOpts {
        int8_only: !kv.contains_key("allow-fallback"),
        ..Default::default()
    };
    let out = kv
        .get("out")
        .cloned()
        .unwrap_or_else(|| format!("{arch}_int{bits}_plan.dfqm"));
    let info = if kv.contains_key("compress") {
        q.save_artifact_compressed(&out, opts)?
    } else {
        q.save_artifact(&out, opts)?
    };
    println!("compiled {}", info.summary());
    println!("saved artifact to {out}");
    Ok(())
}

/// `dfq report <arch|fixture>`: run the instrumented pass pipeline and
/// print the per-pass diagnostics (weight-range spread before/after, the
/// CLE convergence trace, absorbed-bias mass, bias-correction |Δb|) as a
/// table, or as the shared one-line JSON records with `--json`. Built-in
/// fixtures (`two_layer`, `resblock`, `inception`, `deeplab`, `ssd`)
/// need no artifacts directory, so this runs anywhere — including the
/// CI smoke step.
fn cmd_report(rest: &[String]) -> Result<()> {
    let (pos, kv) = flags(rest);
    let arch = pos.first().context("missing <arch|fixture>")?.as_str();
    let json = kv.contains_key("json");
    let bits: u32 = kv.get("bits").map(|s| s.parse()).transpose()?.unwrap_or(8);
    let bc = parse_bc(kv.get("bc").map(|s| s.as_str()).unwrap_or("analytic"))?;
    if bc == BiasCorrMode::Empirical {
        // report runs without a dataset; fail before the pipeline does
        bail!("dfq report supports --bc none|analytic (no calibration data)");
    }
    let model = match arch {
        "two_layer" => dfq::dfq::testutil::two_layer_model(1, true),
        "resblock" => dfq::dfq::testutil::residual_block_model(1),
        "inception" => dfq::dfq::testutil::inception_block_model(1),
        "deeplab" => dfq::dfq::testutil::deeplab_head_model(1),
        "ssd" => dfq::dfq::testutil::ssd_head_model(1),
        _ => {
            let manifest = Manifest::load(dfq::artifacts_dir())?;
            Model::load(manifest.path(&manifest.arch(arch)?.model))?
        }
    };
    if !json {
        println!(
            "{arch}: {} nodes, {} params",
            model.nodes.len(),
            model.param_count()
        );
    }
    let (prep, mut report) =
        dfq::dfq::quantize_data_free_report(&model, &DfqConfig::default())?;
    let scheme = QScheme::int8_asymmetric().with_bits(bits);
    let (q, qreport) = prep.quantize_report(&scheme, bits, bc, None)?;
    report.extend(qreport);
    // the planner verdict completes the story: did the pipeline's output
    // reach a fully-integer execution plan? It joins the report as one
    // more record so both renderings share the pass format.
    let mut plan_summary = None;
    if bits <= 8 {
        match q.pack_int8() {
            Ok(qm) => {
                let mut plan = dfq::dfq::PassReport {
                    name: "plan",
                    changed: qm.num_ops(),
                    ..Default::default()
                };
                plan.metrics.push(("int_layers", qm.int_layers as f64));
                plan.metrics.push(("f32_layers", qm.f32_layers as f64));
                plan.metrics
                    .push(("fallback_ops", qm.fallback_ops() as f64));
                report.passes.push(plan);
                plan_summary = Some(qm.summary());
            }
            Err(e) => {
                // the JSON mode feeds the CI smoke step: a fixture that
                // stops planning is a regression, not a footnote
                if json {
                    return Err(e.context("int8 plan unavailable"));
                }
                plan_summary = Some(format!("unavailable ({e:#})"));
            }
        }
    }
    if json {
        print!("{}", report.json_lines());
    } else {
        print!("{}", report.table());
        if let Some(s) = plan_summary {
            println!("\nplan: {s}");
        }
    }
    Ok(())
}

/// `dfq profile <arch|fixture>`: run DFQ, plan the int8 model with
/// per-op profiling enabled ([`PlanOpts::profile`]), drive a fixed
/// number of serial passes, and print the per-op time / activation-byte
/// / GEMM-kernel table — the runtime twin of `dfq report`'s pass
/// diagnostics. `--json` emits one record per op (plus a totals record)
/// and treats any surviving f32 fallback op as an error, which is what
/// the CI smoke step asserts. Fixtures (`two_layer`, `resblock`,
/// `inception`, `deeplab`, `ssd`) need no artifacts directory.
fn cmd_profile(rest: &[String]) -> Result<()> {
    let (pos, kv) = flags(rest);
    let arch = pos.first().context("missing <arch|fixture>")?.as_str();
    let json = kv.contains_key("json");
    let runs: usize =
        kv.get("runs").map(|s| s.parse()).transpose()?.unwrap_or(8);
    if runs == 0 {
        bail!("--runs must be at least 1");
    }
    let model = match arch {
        "two_layer" => dfq::dfq::testutil::two_layer_model(1, true),
        "resblock" => dfq::dfq::testutil::residual_block_model(1),
        "inception" => dfq::dfq::testutil::inception_block_model(1),
        "deeplab" => dfq::dfq::testutil::deeplab_head_model(1),
        "ssd" => dfq::dfq::testutil::ssd_head_model(1),
        _ => {
            let manifest = Manifest::load(dfq::artifacts_dir())?;
            Model::load(manifest.path(&manifest.arch(arch)?.model))?
        }
    };
    let prep = quantize_data_free(&model, &DfqConfig::default())?;
    let q = prep.quantize(
        &QScheme::int8_asymmetric(),
        8,
        BiasCorrMode::Analytic,
        None,
    )?;
    let opts = dfq::nn::qengine::PlanOpts {
        profile: true,
        ..Default::default()
    };
    let qm = q.pack_int8_opts(opts).context("int8 plan unavailable")?;
    if json && qm.fallback_ops() > 0 {
        // the JSON mode feeds the CI smoke step: a fixture whose plan
        // regresses to f32 fallbacks must fail the step, not pass with
        // quietly different rows
        bail!(
            "plan has {} f32 fallback op(s): {}",
            qm.fallback_ops(),
            qm.summary()
        );
    }
    // drive the serial reference path (one image, no batch parallelism)
    // so the per-op sum is directly comparable to the e2e wall time
    let x = dfq::dfq::testutil::random_input(&q.model, 1, 7);
    qm.run_batch(&x)?; // warm-up: arena growth, first-touch paging
    qm.reset_profile();
    let t0 = std::time::Instant::now();
    for _ in 0..runs {
        qm.run_batch(&x)?;
    }
    let e2e = t0.elapsed().as_secs_f64();
    let prof = qm.profile().expect("profiling was enabled at plan time");
    if json {
        for (i, o) in prof.ops.iter().enumerate() {
            println!(
                "{{\"name\":\"profile/{}/op{i}\",\"node\":{},\"kind\":\"{}\",\
                 \"kernel\":\"{}\",\"int8\":{},\"calls\":{},\
                 \"secs\":{:.9},\"bytes\":{},\"gemm_calls\":{}}}",
                dfq::obs::export::json_escape(arch),
                o.node,
                dfq::obs::export::json_escape(&o.label),
                o.kernel.map(|k| k.name()).unwrap_or("-"),
                o.int8,
                o.calls,
                o.secs,
                o.bytes,
                o.gemm_calls,
            );
        }
        println!(
            "{{\"name\":\"profile/{}\",\"runs\":{},\"op_secs\":{:.9},\
             \"total_secs\":{:.9},\"e2e_secs\":{e2e:.9},\"bytes\":{}}}",
            dfq::obs::export::json_escape(arch),
            prof.runs,
            prof.secs(),
            prof.total_secs,
            prof.bytes(),
        );
    } else {
        println!("{arch}: {}", qm.summary());
        print!("{}", prof.table());
        println!(
            "e2e: {} over {runs} run(s); per-op sum covers {:.1}%",
            dfq::util::bench::fmt_secs(e2e),
            100.0 * prof.secs() / e2e.max(f64::MIN_POSITIVE),
        );
    }
    Ok(())
}

fn cmd_eval(rest: &[String]) -> Result<()> {
    let (pos, kv) = flags(rest);
    let arch = pos.first().context("missing <arch>")?.as_str();
    let mode = kv.get("mode").map(|s| s.as_str()).unwrap_or("dfq");
    let bits: u32 = kv.get("bits").map(|s| s.parse()).transpose()?.unwrap_or(8);
    if let Some(l) = kv.get("limit") {
        std::env::set_var("DFQ_EVAL_LIMIT", l);
    }
    let mut ctx = experiments::Context::new()?;
    let (cfg, scheme, act_bits, bc) = match mode {
        "fp32" => (
            DfqConfig::baseline(),
            QScheme::int8_asymmetric(),
            0,
            BiasCorrMode::None,
        ),
        "baseline" => (
            DfqConfig::baseline(),
            QScheme::int8_asymmetric().with_bits(bits),
            bits,
            BiasCorrMode::None,
        ),
        "dfq" => (
            DfqConfig::default(),
            QScheme::int8_asymmetric().with_bits(bits),
            bits,
            BiasCorrMode::Analytic,
        ),
        _ => bail!("unknown eval mode '{mode}'"),
    };
    let metric = if mode == "fp32" {
        let model = ctx.model(arch)?;
        let prep = quantize_data_free(&model, &cfg)?;
        ctx.eval(arch, &prep.model, &QuantCfg::fp32(&prep.model))?
    } else {
        ctx.eval_quant(arch, &cfg, &scheme, act_bits, bc)?
    };
    println!("{arch} [{mode}, {bits}-bit]: {:.2}%", 100.0 * metric);
    Ok(())
}

fn cmd_serve(rest: &[String]) -> Result<()> {
    let (pos, kv) = flags(rest);
    let requests: usize =
        kv.get("requests").map(|s| s.parse()).transpose()?.unwrap_or(256);
    let rate: f64 =
        kv.get("rate").map(|s| s.parse()).transpose()?.unwrap_or(200.0);
    let batch: usize =
        kv.get("batch").map(|s| s.parse()).transpose()?.unwrap_or(64);
    let seed: u64 =
        kv.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(4242);
    let lanes: usize =
        kv.get("lanes").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let admission_cap: usize = kv
        .get("admission-cap")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0);
    let slo_mix: f64 =
        kv.get("slo-mix").map(|s| s.parse()).transpose()?.unwrap_or(1.0);
    let metrics_dump = kv.get("metrics-dump").map(std::path::PathBuf::from);
    // multi-tenant mode: a directory of compiled artifacts served
    // through the registry (no manifest, no DFQ pipeline at boot)
    if let Some(dir) = kv.get("models") {
        let opts = dfq::serve::demo::RegistryLoadOpts {
            requests,
            rate,
            batch,
            max_resident: kv
                .get("max-resident")
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or(0),
            watch: kv.contains_key("watch"),
            mmap: !kv.contains_key("no-mmap"),
            seed,
            metrics_dump,
            lanes,
            admission_cap,
            slo_mix,
            zipf_s: kv
                .get("zipf")
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or(0.0),
            diurnal_amp: kv
                .get("diurnal-amp")
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or(0.0),
            burst_mult: kv
                .get("burst-mult")
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or(1.0),
        };
        let snaps = dfq::serve::demo::run_registry_load(dir, opts)?;
        for (name, snap) in snaps {
            println!("serve[{name}] {}", snap.report());
        }
        return Ok(());
    }
    let arch = pos
        .first()
        .map(|s| s.as_str())
        .unwrap_or("micronet_v2")
        .to_string();
    // adaptive mode: both variants behind the metrics-driven autoscaler
    if kv.contains_key("autoscale") {
        return dfq::serve::demo::run_adaptive_load(
            &arch, requests, rate, batch, seed,
        );
    }
    // explicit flag wins; otherwise DFQ_BACKEND (default pjrt)
    let backend = match kv.get("backend") {
        Some(s) => dfq::serve::demo::ServeBackend::parse(s)?,
        None => dfq::serve::demo::ServeBackend::from_env(),
    };
    dfq::serve::demo::run_load(
        &arch,
        &dfq::serve::demo::LoadOpts {
            requests,
            rate,
            batch,
            backend,
            seed,
            lanes,
            admission_cap,
            slo_mix,
            metrics_dump,
        },
    )
}

fn cmd_inspect(rest: &[String]) -> Result<()> {
    let (pos, _) = flags(rest);
    let arch = pos.first().context("missing <arch|artifact.dfqm>")?.as_str();
    // a path to a compiled artifact gets the artifact report (a source
    // model container fails with the typed BadMagic explanation)
    if arch.ends_with(".dfqm") && std::path::Path::new(arch).is_file() {
        let info = dfq::artifact::inspect(arch)?;
        println!("compiled artifact {arch}");
        println!("  {}", info.summary());
        // per-section storage table: raw vs stored bytes, compression
        // ratio, CRC over the stored bytes and the BOM flag word
        let stats = dfq::artifact::section_table(arch)?;
        println!(
            "\n  {:<12} {:>10} {:>10} {:>6}  {:>8}  flags",
            "section", "raw", "stored", "ratio", "crc32"
        );
        for s in &stats {
            let raw = s.raw.unwrap_or(s.stored);
            let ratio = if raw == 0 {
                1.0
            } else {
                s.stored as f64 / raw as f64
            };
            let mut f = String::new();
            if s.flags & dfq::artifact::format::FLAG_COMPRESSED != 0 {
                f.push_str("compressed");
            }
            if f.is_empty() {
                f.push_str("raw");
            }
            println!(
                "  {:<12} {:>10} {:>10} {:>5.2}x  {:08x}  {}",
                s.name, raw, s.stored, ratio, s.crc, f
            );
            let unknown = s.unknown_flags();
            if unknown != 0 {
                // newer writers may define more flag bits; surface them
                // without failing the inspect
                println!(
                    "  warning: {} carries unknown flag bits {unknown:#x}",
                    s.name
                );
            }
        }
        return Ok(());
    }
    let manifest = Manifest::load(dfq::artifacts_dir())?;
    let entry = manifest.arch(arch)?;
    let model = Model::load(manifest.path(&entry.model))?;
    println!(
        "{arch} ({}) — {} nodes, {} tensors, {} params",
        entry.task,
        model.nodes.len(),
        model.tensors.len(),
        model.param_count()
    );
    let folded = dfq::dfq::bn_fold::fold(&model)?;
    println!("after folding: {} nodes", folded.nodes.len());
    let pairs = dfq::dfq::equalize::find_pairs(&folded);
    println!("CLE pairs: {}", pairs.len());
    println!("\nper-layer channel precision (eq. 8; min/mean over channels):");
    for n in folded.layers() {
        let w = match &n.op {
            dfq::graph::Op::Conv { w, .. }
            | dfq::graph::Op::ConvT2d { w, .. }
            | dfq::graph::Op::Linear { w, .. } => w,
            _ => unreachable!(),
        };
        let p = dfq::quant::channel_precision(folded.tensor(w)?);
        let mean: f32 = p.iter().sum::<f32>() / p.len() as f32;
        let min = p.iter().cloned().fold(f32::INFINITY, f32::min);
        println!(
            "  node {:>3} {:<22} min {:.3}  mean {:.3}",
            n.id, w, min, mean
        );
    }
    // verify the PJRT contract while we're here
    let rt = Runtime::cpu()?;
    let exec = rt.load_model_exec(&manifest, arch, 1, &folded)?;
    println!(
        "\nPJRT contract OK: {} weight args, {} sites, {} outputs",
        exec.meta.num_weights, exec.meta.num_sites, exec.meta.num_outputs
    );
    Ok(())
}
