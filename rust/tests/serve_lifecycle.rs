//! Registry lifecycle integration tests: eviction + lazy re-load, the
//! resident-model cap, hot swap under a live client (including failed
//! swaps over corrupt replacements), file watching, and deterministic
//! scan order. Companion to `tests/artifact_roundtrip.rs` (format
//! correctness) — this file covers the *serving* lifecycle on top.

use std::path::PathBuf;
use std::time::Duration;

use dfq::dfq::{
    quantize_data_free, testutil, BiasCorrMode, DfqConfig, QuantizedModel,
};
use dfq::nn::qengine::PlanOpts;
use dfq::quant::QScheme;
use dfq::serve::registry::VARIANT_INT8;
use dfq::serve::{Registry, ServeConfig};
use dfq::tensor::Tensor;

fn quantized(seed: u64) -> QuantizedModel {
    let m = testutil::two_layer_model(seed, true);
    let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
    prep.quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::None, None)
        .unwrap()
}

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("dfq-lifecycle-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn evict_then_request_reloads_lazily() {
    let dir = temp_dir("evict");
    let q = quantized(11);
    q.save_artifact(dir.join("m.dfqm"), PlanOpts::default()).unwrap();
    let x = testutil::random_input(&q.model, 1, 3);

    let mut reg = Registry::new(ServeConfig::default());
    assert_eq!(reg.scan_dir(&dir).unwrap(), vec!["m"]);
    let y1 = reg.client("m", VARIANT_INT8).unwrap().infer(x.clone()).unwrap();
    assert_eq!(reg.loaded(), vec!["m"]);

    assert!(reg.evict("m").unwrap());
    assert!(reg.loaded().is_empty(), "evicted model still resident");
    assert!(!reg.evict("m").unwrap(), "double evict must be a no-op");
    assert!(
        reg.metrics("m", VARIANT_INT8).is_err(),
        "an evicted model has no live metrics"
    );

    // the next request re-loads lazily and serves identical outputs
    let y2 = reg.client("m", VARIANT_INT8).unwrap().infer(x).unwrap();
    assert_eq!(y1.data(), y2.data(), "re-loaded plan drifted");
    assert_eq!(reg.loaded(), vec!["m"]);

    // both server generations are accounted for at shutdown
    let snaps = reg.shutdown();
    assert_eq!(snaps.len(), 2, "retired generation lost");
    let total: u64 = snaps.iter().map(|(_, _, s)| s.completed).sum();
    assert_eq!(total, 2);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resident_cap_evicts_least_recently_used() {
    let dir = temp_dir("cap");
    for (name, seed) in [("a", 21), ("b", 22), ("c", 23)] {
        quantized(seed)
            .save_artifact(dir.join(format!("{name}.dfqm")), PlanOpts::default())
            .unwrap();
    }
    let mut reg = Registry::new(ServeConfig {
        max_resident: 2,
        ..ServeConfig::default()
    });
    assert_eq!(reg.scan_dir(&dir).unwrap(), vec!["a", "b", "c"]);

    reg.client("a", VARIANT_INT8).unwrap();
    reg.client("b", VARIANT_INT8).unwrap();
    assert_eq!(reg.loaded(), vec!["a", "b"]);

    // loading c evicts a (least recently used)
    reg.client("c", VARIANT_INT8).unwrap();
    assert_eq!(reg.loaded(), vec!["b", "c"]);

    // recency decides the victim: touch b so c becomes LRU, then load a
    reg.client("b", VARIANT_INT8).unwrap();
    reg.client("a", VARIANT_INT8).unwrap(); // evicts c
    assert_eq!(reg.loaded(), vec!["a", "b"]);

    // an evicted model still serves on demand (lazy re-load), at the
    // cost of evicting the then-LRU one
    let x = Tensor::full(&[1, 3, 8, 8], 0.25);
    let y = reg.client("c", VARIANT_INT8).unwrap().infer(x).unwrap();
    assert_eq!(y.shape()[0], 1);
    assert_eq!(reg.loaded(), vec!["a", "c"]);

    // reloading a non-resident model is just a load: it obeys the cap
    // (evicting the LRU) instead of sneaking past it
    reg.reload("b").unwrap();
    assert_eq!(reg.loaded(), vec!["b", "c"]);

    // a resident reload counts as a touch: after refreshing c, loading
    // a evicts b — not the freshly-swapped c
    reg.reload("c").unwrap();
    reg.client("a", VARIANT_INT8).unwrap();
    assert_eq!(reg.loaded(), vec!["a", "c"]);
    reg.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hot_swap_with_corrupt_replacement_keeps_old_model_serving() {
    let dir = temp_dir("corrupt");
    let path = dir.join("m.dfqm");
    let qa = quantized(31);
    let qb = quantized(32);
    qa.save_artifact(&path, PlanOpts::default()).unwrap();
    let x = testutil::random_input(&qa.model, 1, 9);
    let want_a = qa.pack_int8().unwrap().run(&x).unwrap();
    let want_b = qb.pack_int8().unwrap().run(&x).unwrap();

    let mut reg = Registry::new(ServeConfig::default());
    reg.register_file("m", &path).unwrap();
    let live = reg.live_client("m", VARIANT_INT8).unwrap();
    assert_eq!(live.infer(x.clone()).unwrap().data(), want_a.data());

    // replace the artifact with a truncated copy: the swap must fail
    // with the typed artifact error and the old generation keeps serving
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    let err = reg.reload("m").unwrap_err();
    let msg = format!("{err:#}").to_lowercase();
    assert!(
        msg.contains("truncated") || msg.contains("crc"),
        "expected a typed ArtifactError in the chain, got: {msg}"
    );
    assert_eq!(
        live.infer(x.clone()).unwrap().data(),
        want_a.data(),
        "old model stopped serving after a failed swap"
    );

    // a healthy replacement swaps in through the *same* live client
    qb.save_artifact(&path, PlanOpts::default()).unwrap();
    reg.reload("m").unwrap();
    assert_eq!(
        live.infer(x).unwrap().data(),
        want_b.data(),
        "live client still routed to the old generation"
    );
    reg.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn poll_files_detects_changed_artifacts() {
    let dir = temp_dir("watch");
    let path = dir.join("m.dfqm");
    let qa = quantized(41);
    let qb = quantized(42);
    qa.save_artifact(&path, PlanOpts::default()).unwrap();
    let x = testutil::random_input(&qa.model, 1, 4);
    let want_b = qb.pack_int8().unwrap().run(&x).unwrap();

    let mut reg = Registry::new(ServeConfig::default());
    reg.register_file("m", &path).unwrap();
    let live = reg.live_client("m", VARIANT_INT8).unwrap();
    live.infer(x.clone()).unwrap();

    // nothing changed: no swap attempted
    assert!(reg.poll_files().is_empty());

    // give the filesystem a distinguishable mtime, then rewrite
    std::thread::sleep(Duration::from_millis(50));
    qb.save_artifact(&path, PlanOpts::default()).unwrap();
    let events = reg.poll_files();
    assert_eq!(events.len(), 1, "changed file not detected");
    assert_eq!(events[0].0, "m");
    assert!(events[0].1.is_ok(), "swap failed: {:?}", events[0].1);
    assert_eq!(live.infer(x).unwrap().data(), want_b.data());

    // stamp advanced: a second poll is quiet
    assert!(reg.poll_files().is_empty());

    // a deleted file is not a new version: no swap attempt, the
    // resident plan keeps serving
    std::fs::remove_file(&path).unwrap();
    assert!(reg.poll_files().is_empty(), "deleted file retried forever");
    let y = live.infer(testutil::random_input(&qa.model, 1, 4)).unwrap();
    assert_eq!(y.shape()[0], 1);
    reg.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn reload_warms_the_new_generation_before_the_slot_flips() {
    let dir = temp_dir("warm");
    let path = dir.join("m.dfqm");
    let qa = quantized(61);
    qa.save_artifact(&path, PlanOpts::default()).unwrap();

    let mut reg = Registry::new(ServeConfig::default());
    reg.register_file("m", &path).unwrap();
    let live = reg.live_client("m", VARIANT_INT8).unwrap();

    // a plain lazy load does NOT warm up: live_client only wires the
    // slot, so the generation has served nothing yet
    assert_eq!(reg.metrics("m", VARIANT_INT8).unwrap().completed, 0);

    // hot swap with zero user traffic: the swapped-in generation must
    // already have completed its warm-up batch when reload returns
    std::thread::sleep(Duration::from_millis(50));
    qa.save_artifact(&path, PlanOpts::default()).unwrap();
    reg.reload("m").unwrap();
    let warmed = reg.metrics("m", VARIANT_INT8).unwrap().completed;
    assert!(
        warmed >= 1,
        "reload must pre-run a batch on the new generation, got {warmed}"
    );

    // the warmed generation serves real traffic through the same slot
    let x = testutil::random_input(&qa.model, 1, 6);
    let y = live.infer(x).unwrap();
    assert_eq!(y.shape()[0], 1);
    assert_eq!(
        reg.metrics("m", VARIANT_INT8).unwrap().completed,
        warmed + 1
    );
    reg.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn scan_dir_returns_sorted_names() {
    let dir = temp_dir("sorted");
    // create in deliberately non-sorted order
    for (name, seed) in [("zeta", 51), ("alpha", 52), ("mid", 53)] {
        quantized(seed)
            .save_artifact(dir.join(format!("{name}.dfqm")), PlanOpts::default())
            .unwrap();
    }
    let mut reg = Registry::new(ServeConfig::default());
    assert_eq!(
        reg.scan_dir(&dir).unwrap(),
        vec!["alpha", "mid", "zeta"],
        "scan order must be sorted for reproducible multi-tenant runs"
    );
    std::fs::remove_dir_all(&dir).ok();
}
