//! Integration: the python-AOT → Rust-load contract, end to end.
//!
//! Loads real artifacts (requires `make artifacts`), folds the model in
//! Rust, binds the weights to the PJRT executable, and cross-checks the
//! outputs against the pure-Rust reference engine — FP32 and INT8.
//! Skips (with a message) when artifacts are absent so `cargo test`
//! stays green on a fresh checkout.

use dfq::dfq::{bn_fold, quantize_data_free, BiasCorrMode, DfqConfig};
use dfq::eval::{evaluate, run_all, Backend};
use dfq::graph::io::Dataset;
use dfq::graph::Model;
use dfq::nn::QuantCfg;
use dfq::quant::QScheme;
use dfq::runtime::{Manifest, Runtime};

fn manifest() -> Option<Manifest> {
    match Manifest::load(dfq::artifacts_dir()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping PJRT integration test: {e:#}");
            None
        }
    }
}

#[test]
fn pjrt_matches_engine_fp32_and_int8() {
    let Some(man) = manifest() else { return };
    let arch = "micronet_v2";
    let entry = man.arch(arch).unwrap();
    let model = Model::load(man.path(&entry.model)).unwrap();
    let folded = bn_fold::fold(&model).unwrap();
    let ds = Dataset::load(man.dataset("classification", "test").unwrap())
        .unwrap();

    let rt = Runtime::cpu().unwrap();
    let exec = rt.load_model_exec(&man, arch, 1, &folded).unwrap();

    // FP32 parity
    let cfg = QuantCfg::fp32(&folded);
    let weights = exec.bind_weights(&folded).unwrap();
    let n = 4;
    let y_pjrt = run_all(
        &folded,
        &cfg,
        &ds,
        &Backend::Pjrt { exec: &exec, weights: &weights },
        n,
    )
    .unwrap();
    let y_eng =
        run_all(&folded, &cfg, &ds, &Backend::Engine, n).unwrap();
    let diff = y_pjrt.max_abs_diff(&y_eng);
    let scale = y_eng.abs_max().max(1e-6);
    assert!(
        diff / scale < 1e-3,
        "fp32 mismatch: {diff} (scale {scale})"
    );

    // INT8 DFQ parity
    let prep = quantize_data_free(&model, &DfqConfig::default()).unwrap();
    let q = prep
        .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::Analytic, None)
        .unwrap();
    let exec8 = rt.load_model_exec(&man, arch, 1, &q.model).unwrap();
    let w8 = exec8.bind_weights(&q.model).unwrap();
    let yq_pjrt = run_all(
        &q.model,
        &q.act_cfg,
        &ds,
        &Backend::Pjrt { exec: &exec8, weights: &w8 },
        n,
    )
    .unwrap();
    let yq_eng =
        run_all(&q.model, &q.act_cfg, &ds, &Backend::Engine, n).unwrap();
    let dq = yq_pjrt.max_abs_diff(&yq_eng);
    let sq = yq_eng.abs_max().max(1e-6);
    assert!(dq / sq < 1e-2, "int8 mismatch: {dq} (scale {sq})");
}

#[test]
fn batch64_evaluation_runs() {
    let Some(man) = manifest() else { return };
    let arch = "micronet_v2";
    let entry = man.arch(arch).unwrap();
    let model = Model::load(man.path(&entry.model)).unwrap();
    let folded = bn_fold::fold(&model).unwrap();
    let ds = Dataset::load(man.dataset("classification", "test").unwrap())
        .unwrap();
    let rt = Runtime::cpu().unwrap();
    let exec = rt.load_model_exec(&man, arch, 64, &folded).unwrap();
    let weights = exec.bind_weights(&folded).unwrap();
    let acc = evaluate(
        &folded,
        &QuantCfg::fp32(&folded),
        &ds,
        &Backend::Pjrt { exec: &exec, weights: &weights },
        Some(128),
    )
    .unwrap();
    // the trained corrupted model must be far above chance (0.1)
    assert!(acc > 0.5, "FP32 accuracy suspiciously low: {acc}");
}

#[test]
fn every_arch_contract_validates() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    for (arch, entry) in &man.archs {
        let model = Model::load(man.path(&entry.model)).unwrap();
        let folded = bn_fold::fold(&model).unwrap();
        // contract check happens inside load_model_exec
        let exec = rt.load_model_exec(&man, arch, 1, &folded).unwrap();
        assert_eq!(exec.meta.num_outputs, entry.num_outputs);
    }
}
