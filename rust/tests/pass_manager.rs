//! Pass-manager properties: the instrumented pipeline is bit-for-bit
//! the old hard-coded call sequence; the equalize/absorb passes are
//! idempotent at their fixed point; pair discovery stops at concat/pool
//! boundaries; and the pipeline report carries the promised diagnostics.

use dfq::dfq::{
    absorb, bn_fold, equalize, quantize_data_free, quantize_data_free_report,
    relu6, testutil, BiasCorrMode, DfqConfig,
};
use dfq::graph::{Model, Op};
use dfq::quant::QScheme;

fn fixtures(seed: u64) -> Vec<(&'static str, Model)> {
    vec![
        ("two_layer", testutil::two_layer_model(seed, true)),
        ("resblock", testutil::residual_block_model(seed)),
        ("inception", testutil::inception_block_model(seed)),
    ]
}

/// Acceptance: `quantize_data_free` through the pass manager produces
/// exactly the model the pre-refactor call sequence produced — every
/// tensor bitwise equal, on every fixture.
#[test]
fn pass_pipeline_is_bitwise_equal_to_legacy_sequence() {
    let cfg = DfqConfig::default();
    for (name, m) in fixtures(601) {
        let prep = quantize_data_free(&m, &cfg).unwrap();
        // the exact pre-pass-manager sequence, called directly
        let mut legacy = bn_fold::fold(&m).unwrap();
        relu6::replace_relu6(&mut legacy);
        equalize::equalize(&mut legacy, cfg.eq_iters, cfg.eq_tol).unwrap();
        absorb::absorb_high_biases(&mut legacy, cfg.absorb_sigma).unwrap();

        assert_eq!(
            prep.model.tensors.len(),
            legacy.tensors.len(),
            "{name}: tensor table size drifted"
        );
        for (tname, t) in &legacy.tensors {
            let got = prep.model.tensor(tname).unwrap();
            assert_eq!(
                got.data(),
                t.data(),
                "{name}: tensor '{tname}' drifted from the legacy pipeline"
            );
        }
        assert_eq!(prep.model.nodes, legacy.nodes, "{name}: graph drifted");
    }
}

/// Acceptance: the quantisation-side passes produce the same
/// `QuantizedModel` bits as replicating the old inline loop by hand.
#[test]
fn quantize_passes_match_legacy_quantize_loop() {
    let cfg = DfqConfig::default();
    for (name, m) in fixtures(602) {
        let prep = quantize_data_free(&m, &cfg).unwrap();
        let scheme = QScheme::int8_asymmetric();
        let q = prep
            .quantize(&scheme, 8, BiasCorrMode::Analytic, None)
            .unwrap();
        // legacy: fake-quantise every layer weight in node order, then
        // analytic bias correction against the reference
        let mut legacy = prep.model.clone();
        let ids: Vec<usize> =
            legacy.layers().iter().map(|n| n.id).collect();
        for id in ids {
            let w = match &legacy.node(id).op {
                Op::Conv { w, .. } | Op::Linear { w, .. } => w.clone(),
                _ => unreachable!(),
            };
            let t = legacy.tensors.get_mut(&w).unwrap();
            dfq::quant::quantize_weights_retaining(t, &scheme).unwrap();
        }
        dfq::dfq::bias_correct::analytic(&mut legacy, &prep.reference)
            .unwrap();
        for (tname, t) in &legacy.tensors {
            assert_eq!(
                q.model.tensor(tname).unwrap().data(),
                t.data(),
                "{name}: quantised tensor '{tname}' drifted"
            );
        }
        assert_eq!(
            q.int_weights.len(),
            q.model.layers().len(),
            "{name}: retained codes missing"
        );
    }
}

/// Satellite: running the `equalize` and `absorb` passes a second time
/// on the prepared model is a no-op within `eq_tol` — the pipeline
/// reached its fixed point. (Weight quantisation schemes don't enter:
/// these passes run on the FP32 side, before any grid exists.)
#[test]
fn equalize_and_absorb_are_idempotent_at_fixed_point() {
    let cfg = DfqConfig::default();
    for seed in [611u64, 612] {
        for (name, m) in fixtures(seed) {
            let prep = quantize_data_free(&m, &cfg).unwrap();
            let mut again = prep.model.clone();

            // equalize once more: the very first sweep must already be
            // inside the convergence tolerance
            let trace =
                equalize::equalize_traced(&mut again, cfg.eq_iters, cfg.eq_tol)
                    .unwrap();
            assert!(
                trace[0] <= cfg.eq_tol,
                "{name}/{seed}: re-run CLE moved |log s| by {} (> tol {})",
                trace[0],
                cfg.eq_tol
            );
            // and the weights moved at most by the tolerance, relatively
            for (tname, t) in &prep.model.tensors {
                let got = again.tensor(tname).unwrap();
                let base = t.abs_max().max(1e-6);
                let rel = got.max_abs_diff(t) / base;
                assert!(
                    rel <= 2.0 * cfg.eq_tol,
                    "{name}/{seed}: tensor '{tname}' moved {rel} on re-run"
                );
            }

            // absorb once more: after c = max(0, β − 3γ) was moved, the
            // shifted means leave c = 0 — zero further mass
            let (_, mass) =
                absorb::absorb_high_biases_traced(&mut again, cfg.absorb_sigma)
                    .unwrap();
            assert!(
                mass <= 1e-5,
                "{name}/{seed}: absorb re-run moved mass {mass}"
            );
        }
    }
}

/// CLE pair discovery stops at concat and pool boundaries: the inception
/// fixture has exactly one pair — the squeeze/expand chain inside
/// branch b — and no discovered pair touches a branchy node.
#[test]
fn cle_pairs_stop_at_concat_and_pool_boundaries() {
    let m = testutil::inception_block_model(621);
    let folded = bn_fold::fold(&m).unwrap();
    let pairs = equalize::find_pairs(&folded);
    assert_eq!(pairs.len(), 1, "expected only the in-branch pair: {pairs:?}");
    let pair = pairs[0];
    // both ends are convs whose chain crosses neither pool nor concat:
    // conv a feeds its act, the act feeds conv b directly
    let act = pair.act.expect("relu-linked pair");
    assert_eq!(folded.node(act).inputs, vec![pair.a]);
    assert_eq!(folded.node(pair.b).inputs, vec![act]);
    // and the stem conv (whose act feeds the max-pool) formed no pair
    let stem_conv = folded
        .layers()
        .first()
        .map(|n| n.id)
        .expect("stem conv exists");
    assert!(
        pairs.iter().all(|p| p.a != stem_conv),
        "a pair crossed the max-pool boundary"
    );
}

/// The source-model container round-trips the new graph ops (concat +
/// pool2d JSON codec in `graph::io`).
#[test]
fn source_container_roundtrips_concat_and_pool_nodes() {
    let m = testutil::inception_block_model(641);
    let dir = std::env::temp_dir()
        .join(format!("dfq-passmgr-io-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("inception_src.dfqm");
    m.save(&path).unwrap();
    let back = Model::load(&path).unwrap();
    assert_eq!(back.nodes, m.nodes, "graph drifted through the container");
    assert!(back
        .nodes
        .iter()
        .any(|n| matches!(n.op, Op::Concat)));
    assert!(back
        .nodes
        .iter()
        .any(|n| matches!(n.op, Op::Pool2d { .. })));
    // and the reloaded graph still folds + quantises
    let prep = quantize_data_free(&back, &DfqConfig::default()).unwrap();
    assert!(prep.model.folded);
    std::fs::remove_dir_all(&dir).ok();
}

/// The pipeline report carries the promised diagnostics end to end:
/// spread before/after CLE, the convergence trace, absorbed mass, and
/// the bias-correction magnitude — in both renderings.
#[test]
fn pipeline_report_has_cle_trace_and_bc_magnitude() {
    let m = testutil::inception_block_model(631);
    let (prep, mut report) =
        quantize_data_free_report(&m, &DfqConfig::default()).unwrap();
    let (_, qreport) = prep
        .quantize_report(
            &QScheme::int8_asymmetric(),
            8,
            BiasCorrMode::Analytic,
            None,
        )
        .unwrap();
    report.extend(qreport);

    let eq = report.get("equalize").expect("equalize pass ran");
    assert!(!eq.trace.is_empty(), "CLE trace missing");
    assert!(eq.metric("spread_before").unwrap() >= 1.0);
    assert!(eq.metric("spread_after").unwrap() >= 1.0);
    let bc = report.get("bias_correct").expect("bias_correct pass ran");
    assert!(bc.changed > 0, "no layers corrected");
    assert!(bc.metric("magnitude").unwrap() > 0.0, "no |db| recorded");
    let qz = report.get("quantize").expect("quantize pass ran");
    assert_eq!(qz.metric("int_layers").unwrap() as usize, qz.changed);

    let table = report.table();
    assert!(table.contains("equalize") && table.contains("convergence"));
    let json = report.json_lines();
    assert!(json.contains("\"pass\":\"bias_correct\""));
    assert!(json.lines().count() >= 6, "one JSON record per pass");
}
