//! Differential conformance fuzzing: seeded random graphs over the full
//! op vocabulary (dense/depthwise conv, transposed conv, square /
//! rectangular / global pooling, concat, add, GAP, linear) are compiled
//! end to end and checked against three oracles per graph:
//!
//! 1. the fake-quant reference forward, within the propagated per-op
//!    error budget (no hand-tuned tolerances);
//! 2. the `.dfqm` artifact: the writer is deterministic (same plan →
//!    same bytes) and the reloaded plan reproduces the logits bitwise;
//! 3. forced-scalar dispatch, which must be bitwise-identical to the
//!    native (SIMD) dispatch.
//!
//! The full run covers 200 graphs; `DFQ_CONFORMANCE_QUICK=1` trims it
//! to a 20-graph smoke subset for the forced-scalar CI re-run. Seeds
//! are fixed, so every failure is reproducible by its graph id.

use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;

use dfq::dfq::{quantize_data_free, testutil, BiasCorrMode, DfqConfig};
use dfq::graph::{ActKind, Model, Node, Op, PoolKind, Task};
use dfq::nn::{self, qengine::PlanOpts, qengine::QModel};
use dfq::quant::QScheme;
use dfq::tensor::Tensor;
use dfq::util::rng::Rng;

fn temp_dir() -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("dfq-conformance-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Incremental graph builder: every conv/convT gets the fixture BN
/// recipe (gamma ~ N(1, .3), beta ~ N(.1, .3), mean ~ N(0, .3),
/// var = |N(0, .3)| + .5) so the data-free range estimation has real
/// statistics to work from, plus an optional fused ReLU.
struct Gen {
    nodes: Vec<Node>,
    tensors: BTreeMap<String, Tensor>,
    id: usize,
    rng: Rng,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen {
            nodes: vec![Node { id: 0, inputs: vec![], op: Op::Input }],
            tensors: BTreeMap::new(),
            id: 0,
            rng: Rng::new(seed),
        }
    }

    fn fresh(&mut self) -> usize {
        self.id += 1;
        self.id
    }

    fn push_bn(&mut self, input: usize, ch: usize) -> usize {
        let nid = self.fresh();
        for (p, std, ofs) in [
            ("g", 0.3f32, 1.0f32),
            ("be", 0.3, 0.1),
            ("m", 0.3, 0.0),
            ("v", 0.0, 0.0),
        ] {
            let name = format!("{p}{nid}");
            let mut t = testutil::rand_t(&mut self.rng, &[ch], std);
            t.map_inplace(|x| x + ofs);
            if p == "v" {
                t = testutil::rand_t(&mut self.rng, &[ch], 0.3);
                t.map_inplace(|x| x.abs() + 0.5);
            }
            self.tensors.insert(name, t);
        }
        self.nodes.push(Node {
            id: nid,
            inputs: vec![input],
            op: Op::BatchNorm {
                ch,
                gamma: format!("g{nid}"),
                beta: format!("be{nid}"),
                mean: format!("m{nid}"),
                var: format!("v{nid}"),
            },
        });
        nid
    }

    fn relu(&mut self, input: usize) -> usize {
        let nid = self.fresh();
        self.nodes.push(Node {
            id: nid,
            inputs: vec![input],
            op: Op::Act(ActKind::Relu),
        });
        nid
    }

    /// conv + bn (+ relu). `groups == in_ch` gives the depthwise form.
    #[allow(clippy::too_many_arguments)]
    fn conv(
        &mut self,
        input: usize,
        in_ch: usize,
        out_ch: usize,
        k: usize,
        groups: usize,
        act: bool,
    ) -> usize {
        let nid = self.fresh();
        let w = format!("w{nid}");
        self.tensors.insert(
            w.clone(),
            testutil::rand_t(&mut self.rng, &[out_ch, in_ch / groups, k, k], 0.4),
        );
        self.nodes.push(Node {
            id: nid,
            inputs: vec![input],
            op: Op::Conv {
                w,
                b: None,
                in_ch,
                out_ch,
                k,
                stride: 1,
                pad: k / 2,
                groups,
            },
        });
        let bn = self.push_bn(nid, out_ch);
        if act { self.relu(bn) } else { bn }
    }

    /// transposed conv + bn + relu.
    #[allow(clippy::too_many_arguments)]
    fn convt(
        &mut self,
        input: usize,
        in_ch: usize,
        out_ch: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> usize {
        let nid = self.fresh();
        let w = format!("w{nid}");
        self.tensors.insert(
            w.clone(),
            testutil::rand_t(&mut self.rng, &[out_ch, in_ch, k, k], 0.4),
        );
        self.nodes.push(Node {
            id: nid,
            inputs: vec![input],
            op: Op::ConvT2d { w, b: None, in_ch, out_ch, k, stride, pad },
        });
        let bn = self.push_bn(nid, out_ch);
        self.relu(bn)
    }

    fn pool(
        &mut self,
        input: usize,
        kind: PoolKind,
        k: (usize, usize),
        stride: (usize, usize),
        pad: (usize, usize),
    ) -> usize {
        let nid = self.fresh();
        self.nodes.push(Node {
            id: nid,
            inputs: vec![input],
            op: Op::Pool2d { kind, k, stride, pad, global: false },
        });
        nid
    }

    fn global_pool(&mut self, input: usize, kind: PoolKind) -> usize {
        let nid = self.fresh();
        self.nodes.push(Node {
            id: nid,
            inputs: vec![input],
            op: Op::global_pool2d(kind),
        });
        nid
    }
}

/// One seeded random graph: conv stem on the 8x8 input, then 3–6 body
/// segments drawn from the op vocabulary (each gated on the tracked
/// spatial dims staying valid), then the gap → linear head. All window
/// geometries respect `pad < k` per axis, additions join same-shape
/// branches, and a global pool collapses the rest of the body to 1x1
/// ops — so every generated graph passes validation by construction.
fn random_model(seed: u64) -> Model {
    let mut g = Gen::new(seed);
    let mut ch = if g.rng.below(2) == 0 { 4usize } else { 8 };
    let (mut h, mut w) = (8usize, 8usize);
    let mut cur = g.conv(0, 3, ch, 3, 1, true);
    let body = 3 + g.rng.below(4);
    let mut spatial = true;
    for _ in 0..body {
        match g.rng.below(8) {
            0 | 1 => {
                // dense conv (1x1 once the map is collapsed)
                let k = if spatial && g.rng.below(2) == 0 { 3 } else { 1 };
                let out = if g.rng.below(2) == 0 { 4 } else { 8 };
                cur = g.conv(cur, ch, out, k, 1, true);
                ch = out;
            }
            2 => {
                // depthwise conv
                if spatial {
                    cur = g.conv(cur, ch, ch, 3, ch, true);
                } else {
                    cur = g.conv(cur, ch, ch, 1, 1, true);
                }
            }
            3 => {
                // transposed conv, bounded so the map stays <= 16x16
                if spatial && h * 2 <= 16 && w * 2 <= 16 {
                    let out = if g.rng.below(2) == 0 { 4 } else { 8 };
                    let (k, s, p) = match g.rng.below(3) {
                        0 => (4, 2, 1),
                        1 => (3, 1, 1),
                        _ => (2, 2, 0),
                    };
                    cur = g.convt(cur, ch, out, k, s, p);
                    ch = out;
                    h = (h - 1) * s + k - 2 * p;
                    w = (w - 1) * s + k - 2 * p;
                } else {
                    cur = g.conv(cur, ch, ch, 1, 1, true);
                }
            }
            4 => {
                // pooling: square, or one of the rectangular windows
                if spatial && h >= 2 && w >= 2 {
                    let kind = if g.rng.below(2) == 0 {
                        PoolKind::Max
                    } else {
                        PoolKind::Avg
                    };
                    match g.rng.below(3) {
                        0 => {
                            cur = g.pool(cur, kind, (3, 3), (2, 2), (1, 1));
                            h = (h + 2 - 3) / 2 + 1;
                            w = (w + 2 - 3) / 2 + 1;
                        }
                        1 => {
                            cur = g.pool(cur, kind, (2, 3), (2, 1), (0, 1));
                            h = (h - 2) / 2 + 1;
                        }
                        _ => {
                            cur = g.pool(cur, kind, (1, 3), (1, 2), (0, 1));
                            w = (w + 2 - 3) / 2 + 1;
                        }
                    }
                } else {
                    cur = g.conv(cur, ch, ch, 1, 1, true);
                }
            }
            5 => {
                // residual join: activated branch + pre-activation branch
                let k = if spatial { 3 } else { 1 };
                let a = g.conv(cur, ch, ch, k, 1, true);
                let b = g.conv(cur, ch, ch, 1, 1, false);
                let nid = g.fresh();
                g.nodes.push(Node {
                    id: nid,
                    inputs: vec![a, b],
                    op: Op::Add,
                });
                cur = nid;
            }
            6 => {
                // multi-branch concat of 1x1 heads
                let n_br = 2 + g.rng.below(2);
                let ins: Vec<usize> = (0..n_br)
                    .map(|_| g.conv(cur, ch, 4, 1, 1, true))
                    .collect();
                let nid = g.fresh();
                g.nodes.push(Node {
                    id: nid,
                    inputs: ins,
                    op: Op::Concat,
                });
                ch = 4 * n_br;
                cur = nid;
            }
            _ => {
                // global pool collapses the map once; afterwards 1x1 only
                if spatial {
                    let kind = if g.rng.below(2) == 0 {
                        PoolKind::Max
                    } else {
                        PoolKind::Avg
                    };
                    cur = g.global_pool(cur, kind);
                    h = 1;
                    w = 1;
                    spatial = false;
                } else {
                    cur = g.conv(cur, ch, ch, 1, 1, true);
                }
            }
        }
    }
    let gap = g.fresh();
    g.nodes.push(Node { id: gap, inputs: vec![cur], op: Op::Gap });
    let lin = g.fresh();
    let wl = format!("wl{lin}");
    g.tensors
        .insert(wl.clone(), testutil::rand_t(&mut g.rng, &[10, ch], 0.4));
    let bl = format!("bl{lin}");
    g.tensors
        .insert(bl.clone(), testutil::rand_t(&mut g.rng, &[10], 0.2));
    g.nodes.push(Node {
        id: lin,
        inputs: vec![gap],
        op: Op::Linear { w: wl, b: bl, in_dim: ch, out_dim: 10 },
    });
    Model {
        name: format!("conf{seed}"),
        task: Task::Classification,
        input_shape: [3, 8, 8],
        num_classes: 10,
        nodes: g.nodes,
        outputs: vec![lin],
        tensors: g.tensors,
        meta: BTreeMap::new(),
        act_stats: HashMap::new(),
        folded: false,
    }
}

/// Propagated per-op error budget — the recurrence shared with
/// `tests/qengine_parity.rs`: max-pool is exact on identical inputs,
/// averaging ops add half a step of their input grid, a conv amplifies
/// an upstream diff by at most its max row L1 norm, add sums branch
/// errors and concat takes the worst branch.
fn propagated_budget(q: &dfq::dfq::QuantizedModel) -> f32 {
    let m = &q.model;
    let mut site_scale: HashMap<usize, f32> = HashMap::new();
    let mut row = 1usize;
    for n in &m.nodes {
        if matches!(n.op, Op::Act(_) | Op::Add | Op::Concat) {
            site_scale.insert(n.id, q.act_cfg.rows[row].scale);
            row += 1;
        }
    }
    let l1_of = |w: &str| -> f32 {
        let t = m.tensor(w).unwrap();
        (0..t.shape()[0])
            .map(|o| t.out_channel(o).iter().map(|v| v.abs()).sum())
            .fold(0f32, f32::max)
    };
    let mut e: HashMap<usize, f32> = HashMap::new();
    let mut g: HashMap<usize, f32> = HashMap::new();
    let mut tol = 0f32;
    for n in &m.nodes {
        let (en, gn) = match &n.op {
            Op::Input => (0.0, q.act_cfg.rows[0].scale),
            Op::Conv { w, .. } | Op::ConvT2d { w, .. } => {
                let a = e[&n.inputs[0]] * l1_of(w);
                let fused = m.nodes.iter().any(|c| {
                    matches!(c.op, Op::Act(_))
                        && c.inputs.first() == Some(&n.id)
                });
                if fused {
                    (a, 0.0)
                } else {
                    let s_pre = q
                        .preact_params
                        .iter()
                        .find(|(id, _)| *id == n.id)
                        .map(|(_, p)| p.scale)
                        .unwrap_or(0.0);
                    (a + s_pre, s_pre)
                }
            }
            Op::Act(_) => {
                let s = site_scale[&n.id];
                (e[&n.inputs[0]] + s, s)
            }
            Op::Pool2d { kind, .. } => {
                let (ein, gin) = (e[&n.inputs[0]], g[&n.inputs[0]]);
                match kind {
                    PoolKind::Max => (ein, gin),
                    PoolKind::Avg => (ein + 0.5 * gin, gin),
                }
            }
            Op::Upsample { .. } => (e[&n.inputs[0]], g[&n.inputs[0]]),
            Op::Concat => {
                let s = site_scale[&n.id];
                let worst =
                    n.inputs.iter().map(|i| e[i]).fold(0f32, f32::max);
                (worst + s, s)
            }
            Op::Add => {
                let s = site_scale[&n.id];
                (n.inputs.iter().map(|i| e[i]).sum::<f32>() + s, s)
            }
            Op::Gap => {
                (e[&n.inputs[0]] + 0.5 * g[&n.inputs[0]], g[&n.inputs[0]])
            }
            Op::Linear { w, .. } => {
                tol = tol.max(1.5 * e[&n.inputs[0]] * l1_of(w) + 1e-3);
                (0.0, 0.0)
            }
            Op::BatchNorm { .. } => {
                unreachable!("budget wants a folded model")
            }
        };
        e.insert(n.id, en);
        g.insert(n.id, gn);
    }
    tol
}

/// The harness: every graph must plan fully integer, hit all three
/// oracles, and report zero violations across the whole corpus.
#[test]
fn conformance_random_graphs_match_all_oracles() {
    let quick = std::env::var("DFQ_CONFORMANCE_QUICK")
        .map(|v| v == "1")
        .unwrap_or(false);
    let total = if quick { 20 } else { 200 };
    let dir = temp_dir();
    let schemes = [
        QScheme::int8_asymmetric(),
        QScheme::int8_symmetric(),
        QScheme::per_channel(8),
        QScheme::int8_asymmetric().with_bits(6),
    ];
    let int8_only = PlanOpts { int8_only: true, ..Default::default() };
    let mut op_tally: BTreeMap<&'static str, usize> = BTreeMap::new();
    for i in 0..total {
        let seed = 40_000 + i as u64;
        let model = random_model(seed);
        for n in &model.nodes {
            let label = match &n.op {
                Op::Conv { groups, .. } if *groups > 1 => "conv-dw",
                Op::Conv { .. } => "conv",
                Op::ConvT2d { .. } => "convT",
                Op::Pool2d { global: true, .. } => "pool-global",
                Op::Pool2d { k, .. } if k.0 != k.1 => "pool-rect",
                Op::Pool2d { .. } => "pool-square",
                Op::Concat => "concat",
                Op::Add => "add",
                _ => continue,
            };
            *op_tally.entry(label).or_default() += 1;
        }
        let prep = quantize_data_free(&model, &DfqConfig::default())
            .unwrap_or_else(|e| panic!("graph {seed}: dfq failed: {e:#}"));
        let q = prep
            .quantize(&schemes[i % schemes.len()], 8, BiasCorrMode::None, None)
            .unwrap_or_else(|e| panic!("graph {seed}: quantize failed: {e:#}"));
        let qm = q.pack_int8_opts(int8_only).unwrap_or_else(|e| {
            panic!("graph {seed}: f32 fallback in plan: {e:#}")
        });
        assert_eq!(qm.fallback_ops(), 0, "graph {seed}: {}", qm.summary());

        // oracle 1: fake-quant forward within the propagated budget
        let x = testutil::random_input(&model, 2, seed ^ 0x9e37);
        let y_or = nn::forward(&q.model, &x, &q.act_cfg).unwrap();
        let y = qm.run(&x).unwrap();
        assert_eq!(y.shape(), y_or[0].shape(), "graph {seed}");
        let tol = propagated_budget(&q);
        let diff = y.max_abs_diff(&y_or[0]);
        assert!(
            diff <= tol,
            "graph {seed}: diff {diff} > budget {tol}\n{}",
            qm.summarize()
        );

        // oracle 2: deterministic writer + bitwise reload
        let p1 = dir.join(format!("g{seed}.dfqm"));
        let p2 = dir.join(format!("g{seed}b.dfqm"));
        q.save_artifact(&p1, int8_only).unwrap();
        q.save_artifact(&p2, int8_only).unwrap();
        assert_eq!(
            std::fs::read(&p1).unwrap(),
            std::fs::read(&p2).unwrap(),
            "graph {seed}: same plan must encode to identical bytes"
        );
        let y_disk =
            QModel::from_artifact(&p1).unwrap().run_all(&x).unwrap();
        let y_mem = qm.run_all(&x).unwrap();
        assert_eq!(y_mem.len(), y_disk.len(), "graph {seed}");
        for (a, b) in y_mem.iter().zip(&y_disk) {
            assert_eq!(
                a.data(),
                b.data(),
                "graph {seed}: reloaded plan drifted bitwise"
            );
        }
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();

        // oracle 3: forced-scalar dispatch is bitwise-identical
        let scalar = q
            .pack_int8_opts(PlanOpts {
                int8_only: true,
                force_scalar: true,
                ..Default::default()
            })
            .unwrap();
        assert_eq!(
            y.data(),
            scalar.run(&x).unwrap().data(),
            "graph {seed}: native dispatch drifted from scalar"
        );
    }
    // the full corpus must exercise the whole vocabulary (the quick
    // subset is a smoke run and may miss rare draws)
    if !quick {
        for label in
            ["conv", "conv-dw", "convT", "pool-square", "pool-rect",
             "pool-global", "concat", "add"]
        {
            assert!(
                op_tally.get(label).copied().unwrap_or(0) > 0,
                "conformance corpus never generated a '{label}' op \
                 ({total} graphs): {op_tally:?}"
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}
