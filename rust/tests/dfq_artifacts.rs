//! Integration over the real artifacts: DFQ-level invariants on the
//! trained, corrupted models (skips when `make artifacts` hasn't run).

use dfq::dfq::{bn_fold, equalize, quantize_data_free, BiasCorrMode,
               DfqConfig};
use dfq::eval::{evaluate, Backend};
use dfq::graph::io::Dataset;
use dfq::graph::Model;
use dfq::nn::QuantCfg;
use dfq::quant::QScheme;
use dfq::runtime::Manifest;

fn manifest() -> Option<Manifest> {
    match Manifest::load(dfq::artifacts_dir()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping artifact tests: {e:#}");
            None
        }
    }
}

/// The ill-conditioning corruption is FP32-function-preserving:
/// corrupted and clean models agree on the engine.
#[test]
fn corruption_preserves_fp32_function() {
    let Some(man) = manifest() else { return };
    let entry = man.arch("micronet_v2").unwrap();
    let corrupted =
        bn_fold::fold(&Model::load(man.path(&entry.model)).unwrap()).unwrap();
    let clean = bn_fold::fold(
        &Model::load(man.path(&entry.model_clean)).unwrap(),
    )
    .unwrap();
    let ds = Dataset::load(man.dataset("classification", "test").unwrap())
        .unwrap();
    let x = ds.batch(0, 16);
    let yc = dfq::nn::forward(&corrupted, &x, &QuantCfg::fp32(&corrupted))
        .unwrap();
    let yl =
        dfq::nn::forward(&clean, &x, &QuantCfg::fp32(&clean)).unwrap();
    let rel = yc[0].max_abs_diff(&yl[0]) / yl[0].abs_max().max(1e-6);
    assert!(rel < 5e-2, "corruption changed FP32 function by {rel}");
}

/// The corrupted models actually exhibit the Fig. 2 pathology: at least
/// one layer has >= 20x per-channel range disparity.
#[test]
fn corrupted_models_have_range_disparity() {
    let Some(man) = manifest() else { return };
    for arch in ["micronet_v2", "micronet_v1", "microresnet18"] {
        let entry = man.arch(arch).unwrap();
        let folded =
            bn_fold::fold(&Model::load(man.path(&entry.model)).unwrap())
                .unwrap();
        let mut worst = 1f32;
        for n in folded.layers() {
            let w = match &n.op {
                dfq::graph::Op::Conv { w, .. }
                | dfq::graph::Op::Linear { w, .. } => w,
                _ => unreachable!(),
            };
            let p = dfq::quant::channel_precision(folded.tensor(w).unwrap());
            let (mut lo, mut hi) = (f32::INFINITY, 0f32);
            for &x in &p {
                if x > 0.0 {
                    lo = lo.min(x);
                    hi = hi.max(x);
                }
            }
            worst = worst.max(hi / lo.max(1e-9));
        }
        assert!(worst > 20.0, "{arch}: disparity only {worst}");
    }
}

/// CLE removes the disparity on the real corrupted model (Fig. 6).
#[test]
fn cle_equalizes_real_model() {
    let Some(man) = manifest() else { return };
    let entry = man.arch("micronet_v2").unwrap();
    let mut m =
        bn_fold::fold(&Model::load(man.path(&entry.model)).unwrap()).unwrap();
    dfq::dfq::relu6::replace_relu6(&mut m);
    equalize::equalize(&mut m, 40, 1e-4).unwrap();
    // every *internal* layer's worst channel precision is now sane
    for n in m.layers() {
        let w = match &n.op {
            dfq::graph::Op::Conv { w, .. } => w,
            _ => continue, // classifier head not part of any CLE pair
        };
        let p = dfq::quant::channel_precision(m.tensor(w).unwrap());
        let min = p
            .iter()
            .cloned()
            .filter(|&x| x > 1e-6)
            .fold(f32::INFINITY, f32::min);
        assert!(
            min > 0.005,
            "layer {} still starved after CLE: {min}",
            n.id
        );
    }
}

/// DFQ INT8 recovers within 2% of FP32 on the engine backend
/// (small eval slice keeps this tractable on one core).
#[test]
fn dfq_recovers_on_engine_backend() {
    let Some(man) = manifest() else { return };
    let entry = man.arch("micronet_v2").unwrap();
    let model = Model::load(man.path(&entry.model)).unwrap();
    let ds = Dataset::load(man.dataset("classification", "test").unwrap())
        .unwrap();

    let prep_base = quantize_data_free(&model, &DfqConfig::baseline()).unwrap();
    let fp32 = evaluate(
        &prep_base.model,
        &QuantCfg::fp32(&prep_base.model),
        &ds,
        &Backend::Engine,
        Some(128),
    )
    .unwrap();

    let naive = prep_base
        .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::None, None)
        .unwrap();
    let acc_naive = evaluate(
        &naive.model, &naive.act_cfg, &ds, &Backend::Engine, Some(128),
    )
    .unwrap();

    let prep = quantize_data_free(&model, &DfqConfig::default()).unwrap();
    let q = prep
        .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::Analytic, None)
        .unwrap();
    let acc_dfq =
        evaluate(&q.model, &q.act_cfg, &ds, &Backend::Engine, Some(128))
            .unwrap();

    assert!(fp32 > 0.9, "fp32 {fp32}");
    assert!(acc_naive < 0.5, "naive INT8 should collapse, got {acc_naive}");
    assert!(
        acc_dfq > fp32 - 0.02,
        "DFQ INT8 {acc_dfq} not within 2% of FP32 {fp32}"
    );
}

/// Quantised-model round-trip: save + reload + re-evaluate identically.
#[test]
fn quantized_model_roundtrips() {
    let Some(man) = manifest() else { return };
    let entry = man.arch("micronet_v1").unwrap();
    let model = Model::load(man.path(&entry.model)).unwrap();
    let prep = quantize_data_free(&model, &DfqConfig::default()).unwrap();
    let q = prep
        .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::Analytic, None)
        .unwrap();
    let path = std::env::temp_dir().join("dfq_roundtrip_v1.dfqm");
    q.model.save(&path).unwrap();
    let back = Model::load(&path).unwrap();
    let ds = Dataset::load(man.dataset("classification", "test").unwrap())
        .unwrap();
    let x = ds.batch(0, 8);
    let y0 = dfq::nn::forward(&q.model, &x, &q.act_cfg).unwrap();
    let y1 = dfq::nn::forward(&back, &x, &q.act_cfg).unwrap();
    assert_eq!(y0[0].max_abs_diff(&y1[0]), 0.0);
    std::fs::remove_file(&path).ok();
}
