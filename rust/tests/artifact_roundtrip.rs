//! Artifact round-trip properties: compile → write → read → plan must be
//! bitwise-identical to the in-memory pipeline across models and
//! quantisation schemes; corrupt files must surface as typed
//! [`ArtifactError`]s, never panics; and the registry must serve several
//! reloaded models concurrently with unchanged outputs.

use std::path::PathBuf;

use dfq::artifact::{Artifact, ArtifactError};
use dfq::dfq::{quantize_data_free, testutil, BiasCorrMode, DfqConfig};
use dfq::nn::qengine::{PlanOpts, QModel};
use dfq::quant::QScheme;
use dfq::serve::{registry, Registry, ServeConfig};

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("dfq-roundtrip-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn quantize(
    model: &dfq::graph::Model,
    scheme: &QScheme,
    act_bits: u32,
) -> dfq::dfq::QuantizedModel {
    let prep = quantize_data_free(model, &DfqConfig::default()).unwrap();
    prep.quantize(scheme, act_bits, BiasCorrMode::Analytic, None).unwrap()
}

/// Property: for every (model, scheme, bit-width) combination, the plan
/// reloaded from a written artifact produces bit-for-bit the logits of
/// the in-memory plan — on a multi-image batch, so the batch-parallel
/// path (with its pooled scratch arenas) is exercised too.
#[test]
fn roundtrip_is_bitwise_identical_across_schemes() {
    let dir = temp_dir("schemes");
    let schemes = [
        ("asym", QScheme::int8_asymmetric()),
        ("sym", QScheme::int8_symmetric()),
        ("perchan", QScheme::per_channel(8)),
        ("w6", QScheme::int8_asymmetric().with_bits(6)),
    ];
    let mut cases = 0;
    for seed in [101u64, 102] {
        let models = [
            ("two_layer", testutil::two_layer_model(seed, true)),
            ("resblock", testutil::residual_block_model(seed)),
            // branchy graph: concat + max/avg-pool ops round-trip too
            ("inception", testutil::inception_block_model(seed)),
        ];
        for (mname, model) in models {
            for (sname, scheme) in &schemes {
                let q = quantize(&model, scheme, 8);
                let qm_mem = q
                    .pack_int8_opts(PlanOpts { int8_only: true, ..Default::default() })
                    .unwrap_or_else(|e| {
                        panic!("{mname}/{sname}: fallback in plan: {e:#}")
                    });
                let path =
                    dir.join(format!("{mname}_{sname}_{seed}.dfqm"));
                let info = q
                    .save_artifact(&path, PlanOpts { int8_only: true, ..Default::default() })
                    .unwrap();
                assert_eq!(info.fallback_ops, 0, "{mname}/{sname}");
                let qm_disk = QModel::from_artifact(&path).unwrap();
                assert_eq!(qm_disk.num_ops(), qm_mem.num_ops());

                let x = testutil::random_input(&model, 3, seed + 7);
                let y_mem = qm_mem.run_all(&x).unwrap();
                let y_disk = qm_disk.run_all(&x).unwrap();
                assert_eq!(y_mem.len(), y_disk.len());
                for (a, b) in y_mem.iter().zip(&y_disk) {
                    assert_eq!(a.shape(), b.shape());
                    assert_eq!(
                        a.data(),
                        b.data(),
                        "{mname}/{sname} seed {seed}: reloaded plan \
                         drifted bitwise"
                    );
                }
                cases += 1;
            }
        }
    }
    assert_eq!(cases, 24);
    std::fs::remove_dir_all(&dir).ok();
}

/// The inception-style fixture (concat + max/avg pool codec tags) writes,
/// reloads, and serves with bitwise-identical logits — and its plan
/// report survives the round trip verbatim.
#[test]
fn inception_artifact_roundtrips_bitwise_with_new_op_tags() {
    let dir = temp_dir("inception");
    let model = testutil::inception_block_model(401);
    let q = quantize(&model, &QScheme::int8_asymmetric(), 8);
    let qm_mem = q.pack_int8_opts(PlanOpts { int8_only: true, ..Default::default() }).unwrap();
    let path = dir.join("inception.dfqm");
    let info = q.save_artifact(&path, PlanOpts { int8_only: true, ..Default::default() }).unwrap();
    assert_eq!(info.fallback_ops, 0);
    let qm_disk = QModel::from_artifact(&path).unwrap();
    // the decoded plan is the same plan: op-for-op report equality
    assert_eq!(qm_disk.summarize(), qm_mem.summarize());
    for needle in
        ["concat-requant [int8]", "pool-max [int8]", "pool-avg [int8]"]
    {
        assert!(
            qm_disk.summarize().contains(needle),
            "missing '{needle}' after reload"
        );
    }
    let x = testutil::random_input(&model, 4, 402);
    let y_mem = qm_mem.run_all(&x).unwrap();
    let y_disk = qm_disk.run_all(&x).unwrap();
    for (a, b) in y_mem.iter().zip(&y_disk) {
        assert_eq!(a.data(), b.data(), "reloaded branchy plan drifted");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance path: compile two models to `.dfqm`, reload through the
/// registry, serve both concurrently in one process, and compare every
/// response bit-for-bit against the in-memory pipeline.
#[test]
fn registry_serves_two_reloaded_models_bitwise_identically() {
    let dir = temp_dir("registry");
    let ma = testutil::residual_block_model(201);
    let mb = testutil::two_layer_model(202, true);
    let qa = quantize(&ma, &QScheme::int8_asymmetric(), 8);
    let qb = quantize(&mb, &QScheme::per_channel(8), 8);
    qa.save_artifact(dir.join("alpha.dfqm"), PlanOpts { int8_only: true, ..Default::default() })
        .unwrap();
    qb.save_artifact(dir.join("beta.dfqm"), PlanOpts { int8_only: true, ..Default::default() })
        .unwrap();

    let mut reg = Registry::new(ServeConfig::default());
    assert_eq!(reg.scan_dir(&dir).unwrap(), vec!["alpha", "beta"]);
    let ca = reg.client("alpha", registry::VARIANT_INT8).unwrap();
    let cb = reg.client("beta", registry::VARIANT_INT8).unwrap();
    assert_eq!(reg.loaded().len(), 2, "both models live in one process");

    let xa = testutil::random_input(&ma, 1, 11);
    let xb = testutil::random_input(&mb, 1, 12);
    // submit to both models before receiving anything: both routers are
    // in flight at once
    let pending: Vec<_> = (0..6)
        .map(|i| {
            if i % 2 == 0 {
                ("alpha", ca.submit(xa.clone()).unwrap())
            } else {
                ("beta", cb.submit(xb.clone()).unwrap())
            }
        })
        .collect();
    let want_a = qa.pack_int8().unwrap().run(&xa).unwrap();
    let want_b = qb.pack_int8().unwrap().run(&xb).unwrap();
    for (tag, rx) in pending {
        let y = rx.recv().unwrap().unwrap();
        let want = if tag == "alpha" { &want_a } else { &want_b };
        assert_eq!(y.data(), want.data(), "{tag} served output drifted");
    }
    for (model, completed) in [("alpha", 3), ("beta", 3)] {
        let snap = reg.metrics(model, registry::VARIANT_INT8).unwrap();
        assert_eq!(snap.completed, completed);
    }
    reg.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Corruption matrix: every damaged file yields the matching typed
/// error — and in particular never a panic.
#[test]
fn corrupt_artifacts_yield_typed_errors() {
    let dir = temp_dir("corrupt");
    let model = testutil::residual_block_model(301);
    let q = quantize(&model, &QScheme::int8_asymmetric(), 8);
    let path = dir.join("good.dfqm");
    q.save_artifact(&path, PlanOpts::default()).unwrap();
    let good = std::fs::read(&path).unwrap();

    let write = |tag: &str, bytes: &[u8]| -> PathBuf {
        let p = dir.join(format!("{tag}.dfqm"));
        std::fs::write(&p, bytes).unwrap();
        p
    };

    // bad magic
    let mut bad = good.clone();
    bad[0..4].copy_from_slice(b"XXXX");
    assert!(matches!(
        Artifact::open_typed(&write("magic", &bad)),
        Err(ArtifactError::BadMagic { .. })
    ));

    // a *source model* container is not a compiled artifact
    let src = dir.join("source.dfqm");
    q.model.save(&src).unwrap();
    assert!(matches!(
        Artifact::open_typed(&src),
        Err(ArtifactError::BadMagic { found }) if &found == b"DFQM"
    ));

    // version skew
    let mut bad = good.clone();
    bad[4..8].copy_from_slice(&7u32.to_le_bytes());
    assert!(matches!(
        Artifact::open_typed(&write("version", &bad)),
        Err(ArtifactError::UnsupportedVersion { found: 7 })
    ));

    // truncation at several depths: header, section table, payloads
    for keep in [8, 40, good.len() / 2, good.len() - 9] {
        let p = write(&format!("trunc{keep}"), &good[..keep]);
        let err = Artifact::open_typed(&p).unwrap_err();
        assert!(
            matches!(
                err,
                ArtifactError::Truncated { .. }
                    | ArtifactError::CrcMismatch { .. }
            ),
            "truncation to {keep} bytes gave {err}"
        );
    }

    // flipped payload byte -> CRC mismatch (flip inside the last section)
    let mut bad = good.clone();
    let last = bad.len() - 1;
    bad[last] ^= 0x55;
    assert!(matches!(
        Artifact::open_typed(&write("crc", &bad)),
        Err(ArtifactError::CrcMismatch { .. })
    ));

    // missing file -> typed io error
    assert!(matches!(
        Artifact::open_typed(&dir.join("nonexistent.dfqm")),
        Err(ArtifactError::Io { .. })
    ));

    // the registry propagates load failures as errors, not panics
    let mut reg = Registry::new(ServeConfig::default());
    reg.register_file("bad", dir.join("magic.dfqm")).unwrap();
    assert!(reg.client("bad", registry::VARIANT_INT8).is_err());
    reg.shutdown();

    std::fs::remove_dir_all(&dir).ok();
}
