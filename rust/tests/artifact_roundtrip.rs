//! Artifact round-trip properties: compile → write → read → plan must be
//! bitwise-identical to the in-memory pipeline across models and
//! quantisation schemes — through the owned-copy decode *and* the
//! zero-copy mmap decode, compressed or not; corrupt files must surface
//! as typed [`ArtifactError`]s, never panics; and the registry must
//! serve several reloaded models concurrently with unchanged outputs.

use std::path::PathBuf;

use dfq::artifact::{crc32, section_table, Artifact, ArtifactError};
use dfq::dfq::{quantize_data_free, testutil, BiasCorrMode, DfqConfig};
use dfq::nn::qengine::{PlanOpts, QModel};
use dfq::quant::QScheme;
use dfq::serve::{registry, Registry, ServeConfig};
use dfq::util::rng::Rng;

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("dfq-roundtrip-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn quantize(
    model: &dfq::graph::Model,
    scheme: &QScheme,
    act_bits: u32,
) -> dfq::dfq::QuantizedModel {
    let prep = quantize_data_free(model, &DfqConfig::default()).unwrap();
    prep.quantize(scheme, act_bits, BiasCorrMode::Analytic, None).unwrap()
}

/// Property: for every (model, scheme, bit-width) combination, the plan
/// reloaded from a written artifact produces bit-for-bit the logits of
/// the in-memory plan — on a multi-image batch, so the batch-parallel
/// path (with its pooled scratch arenas) is exercised too.
#[test]
fn roundtrip_is_bitwise_identical_across_schemes() {
    let dir = temp_dir("schemes");
    let schemes = [
        ("asym", QScheme::int8_asymmetric()),
        ("sym", QScheme::int8_symmetric()),
        ("perchan", QScheme::per_channel(8)),
        ("w6", QScheme::int8_asymmetric().with_bits(6)),
    ];
    let mut cases = 0;
    for seed in [101u64, 102] {
        let models = [
            ("two_layer", testutil::two_layer_model(seed, true)),
            ("resblock", testutil::residual_block_model(seed)),
            // branchy graph: concat + max/avg-pool ops round-trip too
            ("inception", testutil::inception_block_model(seed)),
            // v4 codec tags: transposed conv + global pool (deeplab),
            // rectangular + global max/avg pools (ssd)
            ("deeplab", testutil::deeplab_head_model(seed)),
            ("ssd", testutil::ssd_head_model(seed)),
        ];
        for (mname, model) in models {
            for (sname, scheme) in &schemes {
                let q = quantize(&model, scheme, 8);
                let qm_mem = q
                    .pack_int8_opts(PlanOpts { int8_only: true, ..Default::default() })
                    .unwrap_or_else(|e| {
                        panic!("{mname}/{sname}: fallback in plan: {e:#}")
                    });
                let path =
                    dir.join(format!("{mname}_{sname}_{seed}.dfqm"));
                let info = q
                    .save_artifact(&path, PlanOpts { int8_only: true, ..Default::default() })
                    .unwrap();
                assert_eq!(info.fallback_ops, 0, "{mname}/{sname}");
                let qm_disk = QModel::from_artifact(&path).unwrap();
                assert_eq!(qm_disk.num_ops(), qm_mem.num_ops());

                let x = testutil::random_input(&model, 3, seed + 7);
                let y_mem = qm_mem.run_all(&x).unwrap();
                let y_disk = qm_disk.run_all(&x).unwrap();
                assert_eq!(y_mem.len(), y_disk.len());
                for (a, b) in y_mem.iter().zip(&y_disk) {
                    assert_eq!(a.shape(), b.shape());
                    assert_eq!(
                        a.data(),
                        b.data(),
                        "{mname}/{sname} seed {seed}: reloaded plan \
                         drifted bitwise"
                    );
                }
                // the zero-copy mmap decode must match too: same file,
                // tensors served as views into the mapping
                let qm_map = QModel::from_artifact_mmap(&path).unwrap();
                let y_map = qm_map.run_all(&x).unwrap();
                assert_eq!(y_mem.len(), y_map.len());
                for (a, b) in y_mem.iter().zip(&y_map) {
                    assert_eq!(
                        a.data(),
                        b.data(),
                        "{mname}/{sname} seed {seed}: mmap-loaded plan \
                         drifted from the copy load"
                    );
                }
                cases += 1;
            }
        }
    }
    assert_eq!(cases, 40);
    std::fs::remove_dir_all(&dir).ok();
}

/// The inception-style fixture (concat + max/avg pool codec tags) writes,
/// reloads, and serves with bitwise-identical logits — and its plan
/// report survives the round trip verbatim.
#[test]
fn inception_artifact_roundtrips_bitwise_with_new_op_tags() {
    let dir = temp_dir("inception");
    let model = testutil::inception_block_model(401);
    let q = quantize(&model, &QScheme::int8_asymmetric(), 8);
    let qm_mem = q.pack_int8_opts(PlanOpts { int8_only: true, ..Default::default() }).unwrap();
    let path = dir.join("inception.dfqm");
    let info = q.save_artifact(&path, PlanOpts { int8_only: true, ..Default::default() }).unwrap();
    assert_eq!(info.fallback_ops, 0);
    let qm_disk = QModel::from_artifact(&path).unwrap();
    // the decoded plan is the same plan: op-for-op report equality
    assert_eq!(qm_disk.summarize(), qm_mem.summarize());
    for needle in
        ["concat-requant [int8]", "pool-max [int8]", "pool-avg [int8]"]
    {
        assert!(
            qm_disk.summarize().contains(needle),
            "missing '{needle}' after reload"
        );
    }
    let x = testutil::random_input(&model, 4, 402);
    let y_mem = qm_mem.run_all(&x).unwrap();
    let y_disk = qm_disk.run_all(&x).unwrap();
    for (a, b) in y_mem.iter().zip(&y_disk) {
        assert_eq!(a.data(), b.data(), "reloaded branchy plan drifted");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The segmentation/detection fixtures exercise every version-4 codec
/// tag: transposed conv (16), rectangular pools (18) and canonical
/// global pools. Save → reload must preserve the plan report verbatim
/// and the logits bitwise, through both the copy and the mmap decode.
#[test]
fn segdet_artifacts_roundtrip_bitwise_with_v4_op_tags() {
    let dir = temp_dir("segdet");
    let cases = [
        (
            "deeplab",
            testutil::deeplab_head_model(411),
            vec!["convT [int8]", "pool-avg-global [int8]", "pool-max [int8]"],
        ),
        (
            "ssd",
            testutil::ssd_head_model(412),
            vec![
                "pool-max [int8]",
                "pool-max-global [int8]",
                "pool-avg-global [int8]",
            ],
        ),
    ];
    for (mname, model, needles) in cases {
        let q = quantize(&model, &QScheme::int8_asymmetric(), 8);
        let qm_mem = q
            .pack_int8_opts(PlanOpts { int8_only: true, ..Default::default() })
            .unwrap();
        let path = dir.join(format!("{mname}.dfqm"));
        let info = q
            .save_artifact(&path, PlanOpts { int8_only: true, ..Default::default() })
            .unwrap();
        assert_eq!(info.fallback_ops, 0, "{mname}: must plan fully integer");
        let qm_disk = QModel::from_artifact(&path).unwrap();
        assert_eq!(
            qm_disk.summarize(),
            qm_mem.summarize(),
            "{mname}: decoded plan report drifted"
        );
        for needle in needles {
            assert!(
                qm_disk.summarize().contains(needle),
                "{mname}: missing '{needle}' after reload"
            );
        }
        assert!(!qm_disk.summarize().contains("FALLBACK"), "{mname}");
        let x = testutil::random_input(&model, 4, 413);
        let y_mem = qm_mem.run_all(&x).unwrap();
        let y_disk = qm_disk.run_all(&x).unwrap();
        let y_map =
            QModel::from_artifact_mmap(&path).unwrap().run_all(&x).unwrap();
        for (a, b) in y_mem.iter().zip(&y_disk) {
            assert_eq!(a.data(), b.data(), "{mname}: reloaded plan drifted");
        }
        for (a, b) in y_mem.iter().zip(&y_map) {
            assert_eq!(a.data(), b.data(), "{mname}: mmap decode drifted");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance path: compile two models to `.dfqm`, reload through the
/// registry, serve both concurrently in one process, and compare every
/// response bit-for-bit against the in-memory pipeline.
#[test]
fn registry_serves_two_reloaded_models_bitwise_identically() {
    let dir = temp_dir("registry");
    let ma = testutil::residual_block_model(201);
    let mb = testutil::two_layer_model(202, true);
    let qa = quantize(&ma, &QScheme::int8_asymmetric(), 8);
    let qb = quantize(&mb, &QScheme::per_channel(8), 8);
    qa.save_artifact(dir.join("alpha.dfqm"), PlanOpts { int8_only: true, ..Default::default() })
        .unwrap();
    qb.save_artifact(dir.join("beta.dfqm"), PlanOpts { int8_only: true, ..Default::default() })
        .unwrap();

    let mut reg = Registry::new(ServeConfig::default());
    assert_eq!(reg.scan_dir(&dir).unwrap(), vec!["alpha", "beta"]);
    let ca = reg.client("alpha", registry::VARIANT_INT8).unwrap();
    let cb = reg.client("beta", registry::VARIANT_INT8).unwrap();
    assert_eq!(reg.loaded().len(), 2, "both models live in one process");

    let xa = testutil::random_input(&ma, 1, 11);
    let xb = testutil::random_input(&mb, 1, 12);
    // submit to both models before receiving anything: both routers are
    // in flight at once
    let pending: Vec<_> = (0..6)
        .map(|i| {
            if i % 2 == 0 {
                ("alpha", ca.submit(xa.clone()).unwrap())
            } else {
                ("beta", cb.submit(xb.clone()).unwrap())
            }
        })
        .collect();
    let want_a = qa.pack_int8().unwrap().run(&xa).unwrap();
    let want_b = qb.pack_int8().unwrap().run(&xb).unwrap();
    for (tag, rx) in pending {
        let y = rx.recv().unwrap().unwrap();
        let want = if tag == "alpha" { &want_a } else { &want_b };
        assert_eq!(y.data(), want.data(), "{tag} served output drifted");
    }
    for (model, completed) in [("alpha", 3), ("beta", 3)] {
        let snap = reg.metrics(model, registry::VARIANT_INT8).unwrap();
        assert_eq!(snap.completed, completed);
    }
    reg.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Corruption matrix: every damaged file yields the matching typed
/// error — and in particular never a panic.
#[test]
fn corrupt_artifacts_yield_typed_errors() {
    let dir = temp_dir("corrupt");
    let model = testutil::residual_block_model(301);
    let q = quantize(&model, &QScheme::int8_asymmetric(), 8);
    let path = dir.join("good.dfqm");
    q.save_artifact(&path, PlanOpts::default()).unwrap();
    let good = std::fs::read(&path).unwrap();

    let write = |tag: &str, bytes: &[u8]| -> PathBuf {
        let p = dir.join(format!("{tag}.dfqm"));
        std::fs::write(&p, bytes).unwrap();
        p
    };

    // bad magic
    let mut bad = good.clone();
    bad[0..4].copy_from_slice(b"XXXX");
    assert!(matches!(
        Artifact::open_typed(&write("magic", &bad)),
        Err(ArtifactError::BadMagic { .. })
    ));

    // a *source model* container is not a compiled artifact
    let src = dir.join("source.dfqm");
    q.model.save(&src).unwrap();
    assert!(matches!(
        Artifact::open_typed(&src),
        Err(ArtifactError::BadMagic { found }) if &found == b"DFQM"
    ));

    // version skew
    let mut bad = good.clone();
    bad[4..8].copy_from_slice(&7u32.to_le_bytes());
    assert!(matches!(
        Artifact::open_typed(&write("version", &bad)),
        Err(ArtifactError::UnsupportedVersion { found: 7 })
    ));

    // truncation at several depths: header, section table, payloads
    for keep in [8, 40, good.len() / 2, good.len() - 9] {
        let p = write(&format!("trunc{keep}"), &good[..keep]);
        let err = Artifact::open_typed(&p).unwrap_err();
        assert!(
            matches!(
                err,
                ArtifactError::Truncated { .. }
                    | ArtifactError::CrcMismatch { .. }
            ),
            "truncation to {keep} bytes gave {err}"
        );
    }

    // flipped payload byte -> CRC mismatch (flip inside the last section)
    let mut bad = good.clone();
    let last = bad.len() - 1;
    bad[last] ^= 0x55;
    assert!(matches!(
        Artifact::open_typed(&write("crc", &bad)),
        Err(ArtifactError::CrcMismatch { .. })
    ));

    // missing file -> typed io error
    assert!(matches!(
        Artifact::open_typed(&dir.join("nonexistent.dfqm")),
        Err(ArtifactError::Io { .. })
    ));

    // the registry propagates load failures as errors, not panics
    let mut reg = Registry::new(ServeConfig::default());
    reg.register_file("bad", dir.join("magic.dfqm")).unwrap();
    assert!(reg.client("bad", registry::VARIANT_INT8).is_err());
    reg.shutdown();

    std::fs::remove_dir_all(&dir).ok();
}

/// Locate one section's table entry in a raw container image. Layout
/// facts from `artifact::format`: 16-byte header, then 40-byte entries
/// of `{name[16], offset u64, size u64, crc u32, flags u32}`.
fn find_entry(bytes: &[u8], name: &str) -> (usize, usize, usize) {
    let n = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    for i in 0..n {
        let base = 16 + i * 40;
        let raw = &bytes[base..base + 16];
        let end = raw.iter().position(|&b| b == 0).unwrap_or(16);
        if &raw[..end] == name.as_bytes() {
            let off = u64::from_le_bytes(
                bytes[base + 16..base + 24].try_into().unwrap(),
            ) as usize;
            let size = u64::from_le_bytes(
                bytes[base + 24..base + 32].try_into().unwrap(),
            ) as usize;
            return (base, off, size);
        }
    }
    panic!("section '{name}' not found in container");
}

/// First offset of `needle` inside `hay` — for locating a specific op
/// payload in the raw plan stream by its distinctive encoded bytes.
fn find_subslice(hay: &[u8], needle: &[u8]) -> usize {
    hay.windows(needle.len())
        .position(|w| w == needle)
        .expect("op payload pattern not found in plan section")
}

fn le_u32s(vals: &[u32]) -> Vec<u8> {
    vals.iter().flat_map(|v| v.to_le_bytes()).collect()
}

/// Corruption matrix for the version-4 tags: tampered transposed-conv
/// geometry, rectangular-pool shape damage and global-flag corruption
/// hiding behind a *valid* section CRC must all decode to typed
/// [`ArtifactError::Malformed`]; a truncated fixed-point multiplier
/// stream stays a typed error. Never a panic.
#[test]
fn v4_codec_corruption_is_typed_never_a_panic() {
    let dir = temp_dir("v4corrupt");
    let write = |tag: &str, bytes: &[u8]| -> PathBuf {
        let p = dir.join(format!("{tag}.dfqm"));
        std::fs::write(&p, bytes).unwrap();
        p
    };
    let opts = PlanOpts { int8_only: true, ..Default::default() };

    // ---- transposed conv (deeplab: convT 12->8, k4, s2, p1) ----------
    let q = quantize(&testutil::deeplab_head_model(901), &QScheme::int8_asymmetric(), 8);
    let dpath = dir.join("deeplab.dfqm");
    q.save_artifact(&dpath, opts).unwrap();
    let dgood = std::fs::read(&dpath).unwrap();
    assert!(Artifact::open_typed(&dpath).is_ok());
    let (pbase, poff, psize) = find_entry(&dgood, "plan");
    // OP_CONVT payload: tag 16, logical stride 2, logical pad 1, then
    // the inner conv header c_out=8 cig=12 kh=4 kw=4 stride=1 pad=2 g=1
    let mut pat = vec![16u8];
    pat.extend(le_u32s(&[2, 1, 8, 12, 4, 4, 1, 2, 1]));
    let at = poff + find_subslice(&dgood[poff..poff + psize], &pat);
    let patch_plan = |bytes: &mut [u8]| {
        let crc = crc32(&bytes[poff..poff + psize]);
        bytes[pbase + 32..pbase + 36].copy_from_slice(&crc.to_le_bytes());
    };

    // zero logical stride
    let mut bad = dgood.clone();
    bad[at + 1..at + 5].copy_from_slice(&0u32.to_le_bytes());
    patch_plan(&mut bad);
    assert!(
        matches!(
            Artifact::open_typed(&write("convt_stride0", &bad)),
            Err(ArtifactError::Malformed { .. })
        ),
        "zero ConvT stride must be malformed"
    );

    // break the pad' = k-1-pad relation (logical pad 1 -> 3)
    let mut bad = dgood.clone();
    bad[at + 5..at + 9].copy_from_slice(&3u32.to_le_bytes());
    patch_plan(&mut bad);
    assert!(
        matches!(
            Artifact::open_typed(&write("convt_pad", &bad)),
            Err(ArtifactError::Malformed { .. })
        ),
        "inconsistent ConvT pad geometry must be malformed"
    );

    // truncated fixed-point multiplier stream: shrink `mult.fix` so the
    // last requant record is cut mid-way, with a matching CRC
    let (mbase, moff, msize) = find_entry(&dgood, "mult.fix");
    assert!(msize > 8, "deeplab must carry multiplier records");
    let mut bad = dgood.clone();
    let cut = msize - 5;
    bad[mbase + 24..mbase + 32]
        .copy_from_slice(&(cut as u64).to_le_bytes());
    let crc = crc32(&bad[moff..moff + cut]);
    bad[mbase + 32..mbase + 36].copy_from_slice(&crc.to_le_bytes());
    let err = Artifact::open_typed(&write("mult_trunc", &bad)).unwrap_err();
    assert!(
        matches!(
            err,
            ArtifactError::Truncated { .. } | ArtifactError::Malformed { .. }
        ),
        "truncated multiplier stream gave {err}"
    );

    // ---- rectangular / global pools (ssd) ----------------------------
    let q = quantize(&testutil::ssd_head_model(902), &QScheme::int8_asymmetric(), 8);
    let spath = dir.join("ssd.dfqm");
    q.save_artifact(&spath, opts).unwrap();
    let sgood = std::fs::read(&spath).unwrap();
    assert!(Artifact::open_typed(&spath).is_ok());
    let (pbase, poff, psize) = find_entry(&sgood, "plan");
    let patch_plan = |bytes: &mut [u8]| {
        let crc = crc32(&bytes[poff..poff + psize]);
        bytes[pbase + 32..pbase + 36].copy_from_slice(&crc.to_le_bytes());
    };
    // OP_POOL_RECT_INT payload of pool1: tag 18, kind Max(0),
    // global 0, then k=(2,3) stride=(2,1) pad=(0,1)
    let mut rect = vec![18u8, 0, 0];
    rect.extend(le_u32s(&[2, 3, 2, 1, 0, 1]));
    let rat = poff + find_subslice(&sgood[poff..poff + psize], &rect);
    // canonical global pool (Avg): tag 18, kind 1, global 1, all-unit
    let mut glob = vec![18u8, 1, 1];
    glob.extend(le_u32s(&[1, 1, 1, 1, 0, 0]));
    let gat = poff + find_subslice(&sgood[poff..poff + psize], &glob);

    // (field byte offset from the tag, new value, label) — each entry
    // rewrites one u32 of the window geometry or one flag byte
    let rect_cases: [(usize, u32, &str); 2] = [
        (3, 0, "zero pool window on one axis"),
        (3 + 16, 2, "pad >= window on one axis"),
    ];
    for (field, val, label) in rect_cases {
        let mut bad = sgood.clone();
        bad[rat + field..rat + field + 4]
            .copy_from_slice(&val.to_le_bytes());
        patch_plan(&mut bad);
        assert!(
            matches!(
                Artifact::open_typed(&write(&format!("rect{field}"), &bad)),
                Err(ArtifactError::Malformed { .. })
            ),
            "{label} must be malformed"
        );
    }
    // global-flag corruption: an out-of-range flag byte, and a window
    // that contradicts the canonical global form
    let mut bad = sgood.clone();
    bad[gat + 2] = 7;
    patch_plan(&mut bad);
    assert!(
        matches!(
            Artifact::open_typed(&write("glob_flag", &bad)),
            Err(ArtifactError::Malformed { .. })
        ),
        "out-of-range global flag must be malformed"
    );
    let mut bad = sgood.clone();
    bad[gat + 3..gat + 7].copy_from_slice(&3u32.to_le_bytes());
    patch_plan(&mut bad);
    assert!(
        matches!(
            Artifact::open_typed(&write("glob_window", &bad)),
            Err(ArtifactError::Malformed { .. })
        ),
        "non-canonical global window must be malformed"
    );

    std::fs::remove_dir_all(&dir).ok();
}

/// Back-compat: the reader accepts every historical container version.
/// A plan using only pre-v4 tags is encoded identically under v4, so
/// re-stamping its header to 1, 2 or 3 must decode to the same model
/// with bitwise-identical logits.
#[test]
fn historical_container_versions_still_read() {
    let dir = temp_dir("backcompat");
    let model = testutil::residual_block_model(951);
    let q = quantize(&model, &QScheme::int8_asymmetric(), 8);
    let path = dir.join("v4.dfqm");
    q.save_artifact(&path, PlanOpts { int8_only: true, ..Default::default() })
        .unwrap();
    let good = std::fs::read(&path).unwrap();
    let x = testutil::random_input(&model, 2, 952);
    let want = QModel::from_artifact(&path).unwrap().run_all(&x).unwrap();
    for v in [1u32, 2, 3] {
        let mut old = good.clone();
        old[4..8].copy_from_slice(&v.to_le_bytes());
        let p = dir.join(format!("v{v}.dfqm"));
        std::fs::write(&p, &old).unwrap();
        let got = QModel::from_artifact(&p).unwrap().run_all(&x).unwrap();
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.data(), b.data(), "v{v}-stamped container drifted");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// `--compress` artifacts: the weight grid stores smaller than raw,
/// and all three load paths (copy of a plain file, copy of a
/// compressed file, mmap of a compressed file) produce bitwise-equal
/// logits on both the residual and the branchy fixture.
#[test]
fn compressed_artifacts_shrink_wgrid_and_stay_bitwise() {
    let dir = temp_dir("compress");
    let models = [
        ("resblock", testutil::residual_block_model(501)),
        ("inception", testutil::inception_block_model(502)),
    ];
    for (mname, model) in models {
        let q = quantize(&model, &QScheme::int8_asymmetric(), 8);
        let plain = dir.join(format!("{mname}.dfqm"));
        let packed = dir.join(format!("{mname}_z.dfqm"));
        let opts = PlanOpts { int8_only: true, ..Default::default() };
        q.save_artifact(&plain, opts).unwrap();
        q.save_artifact_compressed(&packed, opts).unwrap();

        let stats = section_table(&packed).unwrap();
        let wg = stats.iter().find(|s| s.name == "wgrid.i8").unwrap();
        assert_eq!(
            wg.flags & dfq::artifact::format::FLAG_COMPRESSED,
            dfq::artifact::format::FLAG_COMPRESSED,
            "{mname}: int8 weight codes must actually compress"
        );
        let raw = wg.raw.expect("frame header must be readable");
        assert!(
            wg.stored < raw,
            "{mname}: wgrid.i8 stored {} >= raw {raw}",
            wg.stored
        );
        assert_eq!(wg.unknown_flags(), 0);

        let x = testutil::random_input(&model, 2, 503);
        let y_plain =
            QModel::from_artifact(&plain).unwrap().run_all(&x).unwrap();
        let y_packed =
            QModel::from_artifact(&packed).unwrap().run_all(&x).unwrap();
        let y_packed_map = QModel::from_artifact_mmap(&packed)
            .unwrap()
            .run_all(&x)
            .unwrap();
        for (a, b) in y_plain.iter().zip(&y_packed) {
            assert_eq!(a.data(), b.data(), "{mname}: compression drifted");
        }
        for (a, b) in y_plain.iter().zip(&y_packed_map) {
            assert_eq!(a.data(), b.data(), "{mname}: mmap decode drifted");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The corruption matrix again, through the mmap open path: a mapping
/// that ends before a section does (truncated file), damaged magic and
/// flipped payload bytes must all surface as the same typed errors the
/// owned-copy path reports — never a fault against the mapping.
#[test]
fn corrupt_artifacts_yield_typed_errors_via_mmap() {
    let dir = temp_dir("mmapcorrupt");
    let model = testutil::residual_block_model(601);
    let q = quantize(&model, &QScheme::int8_asymmetric(), 8);
    let path = dir.join("good.dfqm");
    q.save_artifact(&path, PlanOpts::default()).unwrap();
    let good = std::fs::read(&path).unwrap();

    let write = |tag: &str, bytes: &[u8]| -> PathBuf {
        let p = dir.join(format!("{tag}.dfqm"));
        std::fs::write(&p, bytes).unwrap();
        p
    };

    // the good file maps and decodes
    assert!(Artifact::open_mmap_typed(&path).is_ok());

    // truncated mapping at several depths: header, table, payloads
    for keep in [8, 40, good.len() / 2, good.len() - 9] {
        let p = write(&format!("trunc{keep}"), &good[..keep]);
        let err = Artifact::open_mmap_typed(&p).unwrap_err();
        assert!(
            matches!(
                err,
                ArtifactError::Truncated { .. }
                    | ArtifactError::CrcMismatch { .. }
            ),
            "mmap of {keep}-byte truncation gave {err}"
        );
    }

    let mut bad = good.clone();
    bad[0..4].copy_from_slice(b"XXXX");
    assert!(matches!(
        Artifact::open_mmap_typed(&write("magic", &bad)),
        Err(ArtifactError::BadMagic { .. })
    ));

    let mut bad = good.clone();
    let last = bad.len() - 1;
    bad[last] ^= 0x55;
    assert!(matches!(
        Artifact::open_mmap_typed(&write("crc", &bad)),
        Err(ArtifactError::CrcMismatch { .. })
    ));

    assert!(matches!(
        Artifact::open_mmap_typed(&dir.join("nonexistent.dfqm")),
        Err(ArtifactError::Io { .. })
    ));

    std::fs::remove_dir_all(&dir).ok();
}

/// Compressed-section damage stays typed: bit flips in the stored
/// frame trip the CRC *before* the codec runs; a tampered frame header
/// (decompressed-length mismatch, bogus block count) behind a patched
/// CRC fails structurally; and flag-bit corruption — compressed bit on
/// a raw section, or an unknown future bit — is either a typed error
/// or tolerated, never a panic.
#[test]
fn compressed_section_corruption_is_typed_never_a_panic() {
    let dir = temp_dir("zcorrupt");
    let model = testutil::residual_block_model(701);
    let q = quantize(&model, &QScheme::int8_asymmetric(), 8);
    let path = dir.join("z.dfqm");
    q.save_artifact_compressed(&path, PlanOpts { int8_only: true, ..Default::default() })
        .unwrap();
    let good = std::fs::read(&path).unwrap();
    let (base, off, size) = find_entry(&good, "wgrid.i8");
    let flags =
        u32::from_le_bytes(good[base + 36..base + 40].try_into().unwrap());
    assert_eq!(flags, dfq::artifact::format::FLAG_COMPRESSED);

    let write = |tag: &str, bytes: &[u8]| -> PathBuf {
        let p = dir.join(format!("{tag}.dfqm"));
        std::fs::write(&p, bytes).unwrap();
        p
    };
    let patch_crc = |bytes: &mut [u8]| {
        let crc = crc32(&bytes[off..off + size]);
        bytes[base + 32..base + 36].copy_from_slice(&crc.to_le_bytes());
    };

    // bit flips across the compressed payload: the CRC over the stored
    // bytes catches every one before decompression is attempted
    for (i, at) in
        [off, off + size / 3, off + size / 2, off + size - 1].iter().enumerate()
    {
        let mut bad = good.clone();
        bad[*at] ^= 1 << (i % 8).max(1);
        assert!(
            matches!(
                Artifact::open_typed(&write(&format!("flip{i}"), &bad)),
                Err(ArtifactError::CrcMismatch { .. })
            ),
            "flip at stored byte {at} must trip the section CRC"
        );
    }

    // decompressed-length mismatch: bump the frame's raw_len (first u32
    // of the frame) and re-CRC so the codec actually runs
    let mut bad = good.clone();
    let raw_len = u32::from_le_bytes(bad[off..off + 4].try_into().unwrap());
    bad[off..off + 4].copy_from_slice(&(raw_len + 1).to_le_bytes());
    patch_crc(&mut bad);
    let err = Artifact::open_typed(&write("rawlen", &bad)).unwrap_err();
    assert!(
        matches!(
            err,
            ArtifactError::Malformed { .. } | ArtifactError::Truncated { .. }
        ),
        "raw_len mismatch gave {err}"
    );

    // bogus block count behind a valid CRC
    let mut bad = good.clone();
    bad[off + 4..off + 8].copy_from_slice(&0xFFFFu32.to_le_bytes());
    patch_crc(&mut bad);
    let err = Artifact::open_typed(&write("blocks", &bad)).unwrap_err();
    assert!(
        matches!(
            err,
            ArtifactError::Malformed { .. } | ArtifactError::Truncated { .. }
        ),
        "bogus block count gave {err}"
    );

    // flag-bit corruption: marking a *raw* section compressed feeds
    // non-frame bytes to the codec — typed error, not a panic
    let mut bad = good.clone();
    let (bbase, _, _) = find_entry(&good, "bias.i64");
    let bflags =
        u32::from_le_bytes(bad[bbase + 36..bbase + 40].try_into().unwrap());
    assert_eq!(bflags, 0, "bias stays raw so mmap views can point at it");
    bad[bbase + 36..bbase + 40].copy_from_slice(&1u32.to_le_bytes());
    let err = Artifact::open_typed(&write("flagbit", &bad)).unwrap_err();
    assert!(
        matches!(
            err,
            ArtifactError::Malformed { .. } | ArtifactError::Truncated { .. }
        ),
        "compressed-flag on a raw section gave {err}"
    );

    // an unknown future flag bit is tolerated by both load paths
    let mut fwd = good.clone();
    fwd[base + 36..base + 40]
        .copy_from_slice(&(flags | 8).to_le_bytes());
    let p = write("future", &fwd);
    assert!(Artifact::open_typed(&p).is_ok());
    assert!(Artifact::open_mmap_typed(&p).is_ok());
    let stats = section_table(&p).unwrap();
    let wg = stats.iter().find(|s| s.name == "wgrid.i8").unwrap();
    assert_eq!(wg.unknown_flags(), 8, "inspect reports the unknown bit");

    std::fs::remove_dir_all(&dir).ok();
}

/// Codec property test: compress → decompress is the identity on
/// random streams across the block-size edge cases, and on the *real*
/// int8 weight-grid bytes of a compiled fixture — where the entropy
/// coder must also actually shrink the section.
#[test]
fn codec_roundtrips_random_and_real_weight_sections() {
    use dfq::artifact::codec::{compress, decompress};
    let mut rng = Rng::new(909);
    // lengths straddling the 128 KiB block boundary, plus degenerate
    // sizes; content mixes zero runs, repeats and noise so both the LZ
    // and the literal coder paths run
    for len in
        [0usize, 1, 2, 3, 64, 65, 4095, (1 << 17) - 1, 1 << 17, (1 << 17) + 1]
    {
        let data: Vec<u8> = (0..len)
            .map(|i| match (i / 97) % 3 {
                0 => 0u8,
                1 => (i % 11) as u8,
                _ => rng.below(256) as u8,
            })
            .collect();
        let z = compress(&data);
        assert_eq!(decompress(&z).unwrap(), data, "len {len} round trip");
    }

    // pure noise must survive too (stored as RAW blocks internally)
    let noise: Vec<u8> = (0..50_000).map(|_| rng.below(256) as u8).collect();
    assert_eq!(decompress(&compress(&noise)).unwrap(), noise);

    // the real weight grid: near-Gaussian int8 codes, ~7 bit entropy —
    // the acceptance criterion is stored < raw on exactly these bytes
    let dir = temp_dir("codecreal");
    let model = testutil::residual_block_model(801);
    let q = quantize(&model, &QScheme::int8_asymmetric(), 8);
    let path = dir.join("plain.dfqm");
    q.save_artifact(&path, PlanOpts { int8_only: true, ..Default::default() })
        .unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let (_, off, size) = find_entry(&bytes, "wgrid.i8");
    let wgrid = &bytes[off..off + size];
    let z = compress(wgrid);
    assert!(
        z.len() < wgrid.len(),
        "weight grid must shrink: {} -> {}",
        wgrid.len(),
        z.len()
    );
    assert_eq!(decompress(&z).unwrap(), wgrid, "weight grid round trip");
    std::fs::remove_dir_all(&dir).ok();
}
