//! Property-based tests (first-party harness over seeded RNG — no
//! proptest in the offline crate set): randomized models / inputs /
//! schemes, each case checking a paper invariant.

use dfq::dfq::{
    absorb, bn_fold, equalize, quantize_data_free, relu6, BiasCorrMode,
    DfqConfig,
};
use dfq::graph::{Model, Op};
use dfq::nn::{self, ops, QuantCfg};
use dfq::quant::{params_for_range, quantize_weights, QScheme};
use dfq::tensor::Tensor;
use dfq::util::rng::Rng;

use dfq::dfq::testutil;

fn random_two_layer(seed: u64) -> Model {
    testutil::two_layer_model(seed, true)
}

fn random_input(m: &Model, batch: usize, seed: u64) -> Tensor {
    testutil::random_input(m, batch, seed)
}

/// CLE invariance: for 32 random (model, corruption, input) triples the
/// FP32 function is unchanged by equalization (eq. 5-7).
#[test]
fn prop_cle_preserves_fp32_function() {
    for case in 0..32u64 {
        let mut m = bn_fold::fold(&random_two_layer(1000 + case)).unwrap();
        let pairs = equalize::find_pairs(&m);
        assert!(!pairs.is_empty());
        let x = random_input(&m, 2, case);
        let y0 = nn::forward(&m, &x, &QuantCfg::fp32(&m)).unwrap();
        equalize::equalize(&mut m, 30, 1e-4).unwrap();
        let y1 = nn::forward(&m, &x, &QuantCfg::fp32(&m)).unwrap();
        let rel = y0[0].max_abs_diff(&y1[0]) / y0[0].abs_max().max(1e-6);
        assert!(rel < 2e-3, "case {case}: CLE broke FP32 by {rel}");
    }
}

/// Equalization converges: a second full run applies ~unit scales.
#[test]
fn prop_cle_converges() {
    for case in 0..8u64 {
        let mut m = bn_fold::fold(&random_two_layer(2000 + case)).unwrap();
        equalize::equalize(&mut m, 50, 1e-6).unwrap();
        let sweeps = equalize::equalize(&mut m, 50, 1e-4).unwrap();
        assert!(sweeps <= 2, "case {case}: not converged ({sweeps} sweeps)");
    }
}

/// Fake-quant idempotence: fq(fq(x)) == fq(x) on random grids.
#[test]
fn prop_fake_quant_idempotent() {
    let mut rng = Rng::new(7);
    for _ in 0..500 {
        let bits = 2 + rng.below(7) as u32;
        let lo = rng.uniform(-4.0, 0.0);
        let hi = rng.uniform(0.1, 4.0);
        let p = params_for_range(lo, hi, bits, rng.f32() < 0.5);
        let x = rng.uniform(-6.0, 6.0);
        let once = ops::fake_quant_scalar(x, p.scale, p.zero_point, p.n_levels);
        let twice =
            ops::fake_quant_scalar(once, p.scale, p.zero_point, p.n_levels);
        assert_eq!(once, twice, "not idempotent at x={x} p={p:?}");
    }
}

/// Quantisation error bound: |fq(w) - w| <= scale/2 inside the range.
#[test]
fn prop_weight_quant_error_bounded() {
    let mut rng = Rng::new(17);
    for case in 0..50 {
        let n = 8 + rng.below(64);
        let data: Vec<f32> = (0..n * 4).map(|_| rng.normal() * 2.0).collect();
        let t = Tensor::new(&[n, 4], data);
        for scheme in [
            QScheme::int8_asymmetric(),
            QScheme::int8_symmetric(),
            QScheme::per_channel(8),
        ] {
            let mut q = t.clone();
            let ps = quantize_weights(&mut q, &scheme);
            let bound = ps
                .iter()
                .map(|p| p.scale)
                .fold(0f32, f32::max)
                / 2.0
                + 1e-6;
            assert!(
                q.max_abs_diff(&t) <= bound,
                "case {case} {scheme:?}: err {} > {bound}",
                q.max_abs_diff(&t)
            );
        }
    }
}

/// Per-channel quantisation never does worse (L2) than per-tensor.
#[test]
fn prop_per_channel_dominates_per_tensor() {
    let mut rng = Rng::new(23);
    for case in 0..30 {
        let n = 4 + rng.below(16);
        let mut data = Vec::new();
        for c in 0..n {
            let scale = rng.log_uniform(0.01, 10.0);
            for _ in 0..9 {
                data.push(rng.normal() * scale);
            }
            let _ = c;
        }
        let t = Tensor::new(&[n, 9], data);
        let l2 = |scheme: &QScheme| -> f64 {
            let mut q = t.clone();
            quantize_weights(&mut q, scheme);
            q.data()
                .iter()
                .zip(t.data())
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum()
        };
        let pt = l2(&QScheme::int8_asymmetric());
        let pc = l2(&QScheme::per_channel(8));
        assert!(pc <= pt * 1.001, "case {case}: per-channel {pc} > {pt}");
    }
}

/// Bias absorption + analytic BC compose with CLE without breaking the
/// pipeline on random models (smoke over the full API).
#[test]
fn prop_full_pipeline_smoke() {
    for case in 0..12u64 {
        let m = random_two_layer(3000 + case);
        let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
        let q = prep
            .quantize(
                &QScheme::int8_asymmetric(),
                8,
                BiasCorrMode::Analytic,
                None,
            )
            .unwrap();
        let x = random_input(&prep.model, 2, case);
        let yq = nn::forward(&q.model, &x, &q.act_cfg).unwrap();
        assert!(yq[0].data().iter().all(|v| v.is_finite()));
    }
}

/// Model save/load round-trip preserves graph, tensors and stats.
#[test]
fn prop_model_io_roundtrip() {
    for case in 0..6u64 {
        let mut m = bn_fold::fold(&random_two_layer(4000 + case)).unwrap();
        relu6::replace_relu6(&mut m);
        absorb::absorb_high_biases(&mut m, 3.0).unwrap();
        let dir = std::env::temp_dir().join(format!("dfq_prop_{case}"));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.dfqm");
        m.save(&path).unwrap();
        let back = Model::load(&path).unwrap();
        assert_eq!(back.nodes.len(), m.nodes.len());
        assert!(back.folded);
        for (name, t) in &m.tensors {
            assert_eq!(back.tensor(name).unwrap(), t, "tensor {name}");
        }
        for (id, st) in &m.act_stats {
            let b = &back.act_stats[id];
            for (a, c) in st.mean.iter().zip(&b.mean) {
                assert!((a - c).abs() < 1e-5);
            }
        }
        // function identical after round-trip
        let x = random_input(&m, 2, case);
        let y0 = nn::forward(&m, &x, &QuantCfg::fp32(&m)).unwrap();
        let y1 = nn::forward(&back, &x, &QuantCfg::fp32(&back)).unwrap();
        assert_eq!(y0[0].max_abs_diff(&y1[0]), 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// im2col conv == direct conv on random shapes (two independent
/// implementations cross-checked).
#[test]
fn prop_conv_implementations_agree() {
    let mut rng = Rng::new(31);
    for case in 0..20 {
        let (n, c, h) = (1 + rng.below(3), 1 + rng.below(6), 5 + rng.below(8));
        let o = 1 + rng.below(8);
        let k = [1, 3][rng.below(2)];
        let stride = 1 + rng.below(2);
        let pad = k / 2;
        let x = Tensor::new(
            &[n, c, h, h],
            rng.normal_vec(n * c * h * h, 1.0),
        );
        let w = Tensor::new(&[o, c, k, k], rng.normal_vec(o * c * k * k, 0.5));
        let b: Vec<f32> = rng.normal_vec(o, 0.5);
        let a = nn::conv::conv2d(&x, &w, Some(&b), stride, pad, 1);
        let d = nn::conv::conv2d_direct(&x, &w, Some(&b), stride, pad, 1);
        assert!(
            a.max_abs_diff(&d) < 1e-3,
            "case {case}: conv mismatch {}",
            a.max_abs_diff(&d)
        );
    }
}

/// Graph validation rejects malformed models.
#[test]
fn prop_validation_catches_corruption() {
    let m = bn_fold::fold(&random_two_layer(5000)).unwrap();
    // dangling input
    let mut bad = m.clone();
    bad.node_mut(bad.outputs[0]).inputs[0] = 999;
    assert!(bad.validate().is_err());
    // wrong weight shape
    let mut bad = m.clone();
    let wname = match &bad.layers()[0].op {
        Op::Conv { w, .. } => w.clone(),
        _ => unreachable!(),
    };
    bad.tensors.insert(wname, Tensor::zeros(&[1, 1, 1, 1]));
    assert!(bad.validate().is_err());
}
