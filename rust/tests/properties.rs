//! Property-based tests (first-party harness over seeded RNG — no
//! proptest in the offline crate set): randomized models / inputs /
//! schemes, each case checking a paper invariant.

use dfq::dfq::{
    absorb, bn_fold, equalize, quantize_data_free, relu6, BiasCorrMode,
    DfqConfig,
};
use dfq::graph::{Model, Op};
use dfq::nn::{self, ops, QuantCfg};
use dfq::quant::{params_for_range, quantize_weights, QScheme};
use dfq::tensor::Tensor;
use dfq::util::rng::Rng;

use dfq::dfq::testutil;

fn random_two_layer(seed: u64) -> Model {
    testutil::two_layer_model(seed, true)
}

fn random_input(m: &Model, batch: usize, seed: u64) -> Tensor {
    testutil::random_input(m, batch, seed)
}

/// CLE invariance: for 32 random (model, corruption, input) triples the
/// FP32 function is unchanged by equalization (eq. 5-7).
#[test]
fn prop_cle_preserves_fp32_function() {
    for case in 0..32u64 {
        let mut m = bn_fold::fold(&random_two_layer(1000 + case)).unwrap();
        let pairs = equalize::find_pairs(&m);
        assert!(!pairs.is_empty());
        let x = random_input(&m, 2, case);
        let y0 = nn::forward(&m, &x, &QuantCfg::fp32(&m)).unwrap();
        equalize::equalize(&mut m, 30, 1e-4).unwrap();
        let y1 = nn::forward(&m, &x, &QuantCfg::fp32(&m)).unwrap();
        let rel = y0[0].max_abs_diff(&y1[0]) / y0[0].abs_max().max(1e-6);
        assert!(rel < 2e-3, "case {case}: CLE broke FP32 by {rel}");
    }
}

/// Equalization converges: a second full run applies ~unit scales.
#[test]
fn prop_cle_converges() {
    for case in 0..8u64 {
        let mut m = bn_fold::fold(&random_two_layer(2000 + case)).unwrap();
        equalize::equalize(&mut m, 50, 1e-6).unwrap();
        let sweeps = equalize::equalize(&mut m, 50, 1e-4).unwrap();
        assert!(sweeps <= 2, "case {case}: not converged ({sweeps} sweeps)");
    }
}

/// Fake-quant idempotence: fq(fq(x)) == fq(x) on random grids.
#[test]
fn prop_fake_quant_idempotent() {
    let mut rng = Rng::new(7);
    for _ in 0..500 {
        let bits = 2 + rng.below(7) as u32;
        let lo = rng.uniform(-4.0, 0.0);
        let hi = rng.uniform(0.1, 4.0);
        let p = params_for_range(lo, hi, bits, rng.f32() < 0.5);
        let x = rng.uniform(-6.0, 6.0);
        let once = ops::fake_quant_scalar(x, p.scale, p.zero_point, p.n_levels);
        let twice =
            ops::fake_quant_scalar(once, p.scale, p.zero_point, p.n_levels);
        assert_eq!(once, twice, "not idempotent at x={x} p={p:?}");
    }
}

/// Quantisation error bound: |fq(w) - w| <= scale/2 inside the range.
#[test]
fn prop_weight_quant_error_bounded() {
    let mut rng = Rng::new(17);
    for case in 0..50 {
        let n = 8 + rng.below(64);
        let data: Vec<f32> = (0..n * 4).map(|_| rng.normal() * 2.0).collect();
        let t = Tensor::new(&[n, 4], data);
        for scheme in [
            QScheme::int8_asymmetric(),
            QScheme::int8_symmetric(),
            QScheme::per_channel(8),
        ] {
            let mut q = t.clone();
            let ps = quantize_weights(&mut q, &scheme);
            let bound = ps
                .iter()
                .map(|p| p.scale)
                .fold(0f32, f32::max)
                / 2.0
                + 1e-6;
            assert!(
                q.max_abs_diff(&t) <= bound,
                "case {case} {scheme:?}: err {} > {bound}",
                q.max_abs_diff(&t)
            );
        }
    }
}

/// Per-channel quantisation never does worse (L2) than per-tensor.
#[test]
fn prop_per_channel_dominates_per_tensor() {
    let mut rng = Rng::new(23);
    for case in 0..30 {
        let n = 4 + rng.below(16);
        let mut data = Vec::new();
        for c in 0..n {
            let scale = rng.log_uniform(0.01, 10.0);
            for _ in 0..9 {
                data.push(rng.normal() * scale);
            }
            let _ = c;
        }
        let t = Tensor::new(&[n, 9], data);
        let l2 = |scheme: &QScheme| -> f64 {
            let mut q = t.clone();
            quantize_weights(&mut q, scheme);
            q.data()
                .iter()
                .zip(t.data())
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum()
        };
        let pt = l2(&QScheme::int8_asymmetric());
        let pc = l2(&QScheme::per_channel(8));
        assert!(pc <= pt * 1.001, "case {case}: per-channel {pc} > {pt}");
    }
}

/// Bias absorption + analytic BC compose with CLE without breaking the
/// pipeline on random models (smoke over the full API).
#[test]
fn prop_full_pipeline_smoke() {
    for case in 0..12u64 {
        let m = random_two_layer(3000 + case);
        let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
        let q = prep
            .quantize(
                &QScheme::int8_asymmetric(),
                8,
                BiasCorrMode::Analytic,
                None,
            )
            .unwrap();
        let x = random_input(&prep.model, 2, case);
        let yq = nn::forward(&q.model, &x, &q.act_cfg).unwrap();
        assert!(yq[0].data().iter().all(|v| v.is_finite()));
    }
}

/// Model save/load round-trip preserves graph, tensors and stats.
#[test]
fn prop_model_io_roundtrip() {
    for case in 0..6u64 {
        let mut m = bn_fold::fold(&random_two_layer(4000 + case)).unwrap();
        relu6::replace_relu6(&mut m);
        absorb::absorb_high_biases(&mut m, 3.0).unwrap();
        let dir = std::env::temp_dir().join(format!("dfq_prop_{case}"));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.dfqm");
        m.save(&path).unwrap();
        let back = Model::load(&path).unwrap();
        assert_eq!(back.nodes.len(), m.nodes.len());
        assert!(back.folded);
        for (name, t) in &m.tensors {
            assert_eq!(back.tensor(name).unwrap(), t, "tensor {name}");
        }
        for (id, st) in &m.act_stats {
            let b = &back.act_stats[id];
            for (a, c) in st.mean.iter().zip(&b.mean) {
                assert!((a - c).abs() < 1e-5);
            }
        }
        // function identical after round-trip
        let x = random_input(&m, 2, case);
        let y0 = nn::forward(&m, &x, &QuantCfg::fp32(&m)).unwrap();
        let y1 = nn::forward(&back, &x, &QuantCfg::fp32(&back)).unwrap();
        assert_eq!(y0[0].max_abs_diff(&y1[0]), 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// im2col conv == direct conv on random shapes (two independent
/// implementations cross-checked).
#[test]
fn prop_conv_implementations_agree() {
    let mut rng = Rng::new(31);
    for case in 0..20 {
        let (n, c, h) = (1 + rng.below(3), 1 + rng.below(6), 5 + rng.below(8));
        let o = 1 + rng.below(8);
        let k = [1, 3][rng.below(2)];
        let stride = 1 + rng.below(2);
        let pad = k / 2;
        let x = Tensor::new(
            &[n, c, h, h],
            rng.normal_vec(n * c * h * h, 1.0),
        );
        let w = Tensor::new(&[o, c, k, k], rng.normal_vec(o * c * k * k, 0.5));
        let b: Vec<f32> = rng.normal_vec(o, 0.5);
        let a = nn::conv::conv2d(&x, &w, Some(&b), stride, pad, 1);
        let d = nn::conv::conv2d_direct(&x, &w, Some(&b), stride, pad, 1);
        assert!(
            a.max_abs_diff(&d) < 1e-3,
            "case {case}: conv mismatch {}",
            a.max_abs_diff(&d)
        );
    }
}

/// Graph validation rejects malformed models.
#[test]
fn prop_validation_catches_corruption() {
    let m = bn_fold::fold(&random_two_layer(5000)).unwrap();
    // dangling input
    let mut bad = m.clone();
    bad.node_mut(bad.outputs[0]).inputs[0] = 999;
    assert!(bad.validate().is_err());
    // wrong weight shape
    let mut bad = m.clone();
    let wname = match &bad.layers()[0].op {
        Op::Conv { w, .. } => w.clone(),
        _ => unreachable!(),
    };
    bad.tensors.insert(wname, Tensor::zeros(&[1, 1, 1, 1]));
    assert!(bad.validate().is_err());
}

/// Chain fixture for the through-pool CLE property: conv → relu →
/// `pool_op` → conv → relu → gap → linear, biased convs, pre-folded.
fn pool_chain_model(pool_op: Op, seed: u64) -> Model {
    use dfq::graph::{ActKind, Node, Task};
    use std::collections::{BTreeMap, HashMap};
    let mut rng = Rng::new(seed);
    let mut tensors = BTreeMap::new();
    let t = |rng: &mut Rng, shape: &[usize], std: f32| {
        Tensor::new(shape, rng.normal_vec(shape.iter().product(), std))
    };
    tensors.insert("w1".into(), t(&mut rng, &[8, 3, 3, 3], 0.4));
    tensors.insert("b1".into(), t(&mut rng, &[8], 0.2));
    tensors.insert("w4".into(), t(&mut rng, &[8, 8, 3, 3], 0.4));
    tensors.insert("b4".into(), t(&mut rng, &[8], 0.2));
    tensors.insert("wl".into(), t(&mut rng, &[10, 8], 0.4));
    tensors.insert("bl".into(), t(&mut rng, &[10], 0.2));
    let nodes = vec![
        Node { id: 0, inputs: vec![], op: Op::Input },
        Node {
            id: 1,
            inputs: vec![0],
            op: Op::Conv {
                w: "w1".into(),
                b: Some("b1".into()),
                in_ch: 3,
                out_ch: 8,
                k: 3,
                stride: 1,
                pad: 1,
                groups: 1,
            },
        },
        Node { id: 2, inputs: vec![1], op: Op::Act(ActKind::Relu) },
        Node { id: 3, inputs: vec![2], op: pool_op },
        Node {
            id: 4,
            inputs: vec![3],
            op: Op::Conv {
                w: "w4".into(),
                b: Some("b4".into()),
                in_ch: 8,
                out_ch: 8,
                k: 3,
                stride: 1,
                pad: 1,
                groups: 1,
            },
        },
        Node { id: 5, inputs: vec![4], op: Op::Act(ActKind::Relu) },
        Node { id: 6, inputs: vec![5], op: Op::Gap },
        Node {
            id: 7,
            inputs: vec![6],
            op: Op::Linear {
                w: "wl".into(),
                b: "bl".into(),
                in_dim: 8,
                out_dim: 10,
            },
        },
    ];
    Model {
        name: "test_poolchain".into(),
        task: Task::Classification,
        input_shape: [3, 8, 8],
        num_classes: 10,
        nodes,
        outputs: vec![7],
        tensors,
        meta: BTreeMap::new(),
        act_stats: HashMap::new(),
        folded: true,
    }
}

/// CLE-through-pool equivariance, pinned *bitwise*: max and avg pooling
/// (square, rectangular and global) commute with per-channel positive
/// scaling, so applying power-of-two scales `s_i` to the producer and
/// `1/s_i` to the consumer across the pool leaves the f32 forward
/// bit-for-bit unchanged (power-of-two scaling only shifts exponents,
/// so every conv product and pool average is float-exact).
#[test]
fn prop_cle_through_pool_scaling_is_bitwise_equivariant() {
    use dfq::graph::PoolKind;
    let pools = [
        Op::pool2d(PoolKind::Max, 3, 2, 1),
        Op::pool2d(PoolKind::Avg, 3, 2, 1),
        Op::Pool2d {
            kind: PoolKind::Max,
            k: (2, 3),
            stride: (2, 1),
            pad: (0, 1),
            global: false,
        },
        Op::global_pool2d(PoolKind::Avg),
    ];
    for (pi, pool_op) in pools.iter().enumerate() {
        for case in 0..8u64 {
            let seed = 7000 + 100 * pi as u64 + case;
            let m0 = pool_chain_model(pool_op.clone(), seed);
            let pairs = equalize::find_pairs(&m0);
            assert_eq!(pairs.len(), 1, "pool {pi}: {pairs:?}");
            let p = pairs[0];
            assert!(p.through_pool, "pool {pi}: pair must cross the pool");
            assert!(p.act.is_some());
            let x = random_input(&m0, 2, seed ^ 0xabc);
            let y0 = nn::forward(&m0, &x, &QuantCfg::fp32(&m0)).unwrap();
            let mut m = m0.clone();
            let mut rng = Rng::new(seed);
            let s: Vec<f32> = (0..8)
                .map(|_| (2f32).powi(rng.below(5) as i32 - 2))
                .collect();
            {
                let w = m.tensor_mut("w1").unwrap();
                for (i, &si) in s.iter().enumerate() {
                    w.scale_out_channel(i, 1.0 / si);
                }
                let b = m.tensor_mut("b1").unwrap();
                for (i, &si) in s.iter().enumerate() {
                    b.data_mut()[i] /= si;
                }
                let w = m.tensor_mut("w4").unwrap();
                for (i, &si) in s.iter().enumerate() {
                    w.scale_in_channel(i, si);
                }
            }
            let y1 = nn::forward(&m, &x, &QuantCfg::fp32(&m)).unwrap();
            assert_eq!(
                y0[0].data(),
                y1[0].data(),
                "pool {pi} case {case}: scaling across the pool changed \
                 the f32 forward"
            );
        }
    }
}

/// Pair discovery still stops where it must: output splits, concat
/// (channel identity lost), add, gap and upsample all end a chain; only
/// single-consumer act/pool hops survive. Pinned against all four
/// branchy fixtures.
#[test]
fn prop_cle_discovery_stops_at_splits_and_boundaries() {
    // deeplab: exactly one pair, and it crosses the stem max pool
    let m = bn_fold::fold(&testutil::deeplab_head_model(31)).unwrap();
    let pairs = equalize::find_pairs(&m);
    assert_eq!(pairs.len(), 1, "{pairs:?}");
    assert!(pairs[0].through_pool);
    for p in &pairs {
        assert!(matches!(m.node(p.a).op, Op::Conv { .. }));
        assert!(matches!(m.node(p.b).op, Op::Conv { .. }));
    }
    // ssd: every chain hits a split, a global pool feeding concat, or
    // the gap head — no eligible pair anywhere
    let m = bn_fold::fold(&testutil::ssd_head_model(32)).unwrap();
    assert!(equalize::find_pairs(&m).is_empty());
    // inception: only the in-branch squeeze→expand pair; its chain
    // crosses no pool
    let m = bn_fold::fold(&testutil::inception_block_model(33)).unwrap();
    let pairs = equalize::find_pairs(&m);
    assert_eq!(pairs.len(), 1, "{pairs:?}");
    assert!(!pairs[0].through_pool);
    // resblock: dw→pw pair only; the chain out of the pw conv stops at
    // the residual add
    let m = bn_fold::fold(&testutil::residual_block_model(34)).unwrap();
    let pairs = equalize::find_pairs(&m);
    assert_eq!(pairs.len(), 1, "{pairs:?}");
    assert!(!pairs[0].through_pool);
}

/// Full CLE (arbitrary eq.-11 scales, iterated to convergence) on the
/// through-pool fixture still preserves the FP32 function to float
/// noise — the through-pool extension introduces no drift.
#[test]
fn prop_cle_through_pool_preserves_fp32_on_deeplab() {
    for case in 0..8u64 {
        let mut m =
            bn_fold::fold(&testutil::deeplab_head_model(8100 + case)).unwrap();
        let x = random_input(&m, 2, case);
        let y0 = nn::forward(&m, &x, &QuantCfg::fp32(&m)).unwrap();
        equalize::equalize(&mut m, 30, 1e-4).unwrap();
        let y1 = nn::forward(&m, &x, &QuantCfg::fp32(&m)).unwrap();
        let rel = y0[0].max_abs_diff(&y1[0]) / y0[0].abs_max().max(1e-6);
        assert!(rel < 2e-3, "case {case}: through-pool CLE broke FP32 by {rel}");
    }
}

/// A pool window lying entirely in the padding (reachable with
/// rectangular `k` + large pad on the short axis) would make the avg
/// path divide by a zero tap count. The semantics are defined at
/// validation instead: `pad < k` per axis, so every admitted window
/// keeps at least one real tap — and at the maximal legal pad the avg
/// kernel still produces only finite values.
#[test]
fn prop_pool_empty_window_is_rejected_at_validation() {
    use dfq::graph::PoolKind;
    // maximal legal pad on both axes is fine
    let rect = |k: (usize, usize), pad: (usize, usize)| Op::Pool2d {
        kind: PoolKind::Avg,
        k,
        stride: (1, 1),
        pad,
        global: false,
    };
    pool_chain_model(rect((2, 3), (1, 2)), 61).validate().unwrap();
    // pad >= k on either axis admits an all-padding window
    for (k, pad) in [((2, 3), (2, 2)), ((2, 3), (0, 3)), ((1, 3), (1, 1))] {
        let err = pool_chain_model(rect(k, pad), 62).validate().unwrap_err();
        assert!(
            err.to_string().contains("pad"),
            "k={k:?} pad={pad:?}: wrong error: {err:#}"
        );
    }
    // zero-sized windows and non-canonical global forms are structural
    // errors too, never runtime surprises
    assert!(pool_chain_model(rect((0, 3), (0, 1)), 63).validate().is_err());
    let bad_global = Op::Pool2d {
        kind: PoolKind::Max,
        k: (2, 2),
        stride: (1, 1),
        pad: (0, 0),
        global: true,
    };
    assert!(pool_chain_model(bad_global, 64).validate().is_err());

    // the runtime pin: every window of a maximal-pad avg pool has at
    // least one real tap, so no output is NaN/inf
    let mut rng = Rng::new(65);
    let x = Tensor::new(&[1, 1, 4, 5], rng.normal_vec(20, 1.0));
    let y = ops::avg_pool2d_rect(&x, (2, 3), (1, 1), (1, 2));
    assert!(
        y.data().iter().all(|v| v.is_finite()),
        "avg pool with pad = k-1 produced non-finite outputs"
    );
}
