//! Property tests for the quantisation grid and the true-int8 execution
//! path: grid invariants, QTensor round-trips, and int8-GEMM vs
//! fake-quant-f32 parity on random layer shapes (dense + depthwise) and
//! on a full DFQ-quantised model.

use dfq::dfq::{quantize_data_free, BiasCorrMode, DfqConfig};
use dfq::dfq::testutil;
use dfq::nn::ops::{clip_act, fake_quant, fake_quant_scalar};
use dfq::nn::qengine::{QActTensor, QConv};
use dfq::nn::{self, conv, SiteCfg};
use dfq::quant::{params_for_range, quantize_weights_retaining, QScheme};
use dfq::tensor::{QTensor, Tensor};
use dfq::util::rng::Rng;

fn rand_t(rng: &mut Rng, shape: &[usize], std: f32) -> Tensor {
    Tensor::new(shape, rng.normal_vec(shape.iter().product(), std))
}

/// Asymmetric grids must represent zero exactly (paper §5: zero padding
/// has to be lossless), for any range and bit-width.
#[test]
fn prop_asymmetric_grid_represents_zero_exactly() {
    let mut rng = Rng::new(100);
    for _ in 0..500 {
        let bits = 2 + rng.below(7) as u32;
        let lo = rng.uniform(-8.0, 4.0);
        let hi = rng.uniform(lo + 0.01, lo + 12.0);
        let p = params_for_range(lo, hi, bits, false);
        assert_eq!(
            p.zero_point.fract(),
            0.0,
            "zero point {} not integral for [{lo}, {hi}] @ {bits}b",
            p.zero_point
        );
        let z = fake_quant_scalar(0.0, p.scale, p.zero_point, p.n_levels);
        assert_eq!(z, 0.0, "zero not representable for [{lo}, {hi}] @ {bits}b");
    }
}

/// QTensor pack→unpack round-trip error is ≤ scale/2 per element, for
/// per-tensor and per-channel grids and both storage signednesses.
#[test]
fn prop_qtensor_roundtrip_error_bounded() {
    let mut rng = Rng::new(101);
    for case in 0..64u64 {
        let c_out = 1 + rng.below(6);
        let per = 1 + rng.below(24);
        let mut t = rand_t(&mut rng, &[c_out, per], 1.0);
        for o in 0..c_out {
            // spread channel magnitudes over two decades
            let s = rng.log_uniform(0.05, 5.0);
            t.scale_out_channel(o, s);
        }
        for per_channel in [false, true] {
            for signed in [false, true] {
                let params = if per_channel {
                    t.channel_ranges()
                        .into_iter()
                        .map(|(lo, hi)| params_for_range(lo, hi, 8, false))
                        .collect::<Vec<_>>()
                } else {
                    vec![params_for_range(t.min(), t.max(), 8, false)]
                };
                let q = QTensor::quantize(&t, &params, signed).unwrap();
                let back = q.dequantize();
                for o in 0..c_out {
                    let s = q.param_for_channel(o).scale;
                    for (a, b) in
                        t.out_channel(o).iter().zip(back.out_channel(o))
                    {
                        assert!(
                            (a - b).abs() <= s / 2.0 + 1e-6,
                            "case {case}: err {} > {}",
                            (a - b).abs(),
                            s / 2.0
                        );
                    }
                }
            }
        }
    }
}

/// Build a random quantised conv layer + input and return
/// (packed int conv, quantised input, fake-quant weights, bias, site).
#[allow(clippy::type_complexity, clippy::too_many_arguments)]
fn random_layer(
    rng: &mut Rng,
    c_in: usize,
    c_out: usize,
    k: usize,
    stride: usize,
    pad: usize,
    groups: usize,
    per_channel: bool,
    clip_hi: f32,
) -> (QConv, QActTensor, Tensor, Vec<f32>, SiteCfg) {
    let scheme = if per_channel {
        QScheme::per_channel(8)
    } else {
        QScheme::int8_asymmetric()
    };
    let mut w = rand_t(rng, &[c_out, c_in / groups, k, k], 0.4);
    let (_, codes) = quantize_weights_retaining(&mut w, &scheme).unwrap();
    let b: Vec<f32> = rng.normal_vec(c_out, 0.2);

    let x = rand_t(rng, &[2, c_in, 9, 9], 1.0);
    let in_qp = params_for_range(x.min(), x.max(), 8, false);
    let xq = QActTensor::quantize(&x, &in_qp);

    // output grid from the oracle's pre-activation range (data-free
    // ranges would come from BN stats; any valid grid works here)
    let y = conv::conv2d(&xq.dequantize(), &w, Some(&b), stride, pad, groups);
    let hi = y.max().min(clip_hi).max(0.1);
    let p = params_for_range(0.0, hi, 8, false);
    let row = SiteCfg {
        scale: p.scale,
        zero_point: p.zero_point,
        n_levels: p.n_levels,
        clip_hi,
    };
    let qc =
        QConv::pack(&codes, &b, stride, pad, groups, &in_qp, Some(&row))
            .unwrap();
    (qc, xq, w, b, row)
}

/// Fused int8 conv (dense + depthwise, random shapes/schemes) matches
/// the fake-quant f32 oracle within ONE quantisation step per element.
#[test]
fn prop_int8_conv_matches_fake_quant_oracle() {
    let mut rng = Rng::new(102);
    for case in 0..24u64 {
        let depthwise = case % 3 == 2;
        let k = [1, 3][rng.below(2)];
        let (c_in, c_out, groups, k) = if depthwise {
            let c = 2 + rng.below(6);
            (c, c, c, 3)
        } else {
            (1 + rng.below(6), 1 + rng.below(8), 1, k)
        };
        let stride = 1 + rng.below(2);
        let pad = k / 2;
        let per_channel = case % 2 == 0;
        let clip_hi = if case % 4 == 0 { 6.0 } else { f32::INFINITY };
        let (qc, xq, w, b, row) = random_layer(
            &mut rng, c_in, c_out, k, stride, pad, groups, per_channel,
            clip_hi,
        );

        // oracle: f32 conv over the SAME on-grid operands, then the
        // engine's clip + fake-quant at the site
        let mut y_or = conv::conv2d(
            &xq.dequantize(),
            &w,
            Some(&b),
            stride,
            pad,
            groups,
        );
        clip_act(&mut y_or, row.clip_hi);
        fake_quant(&mut y_or, row.scale, row.zero_point, row.n_levels);

        let y_int = qc.run_q(&xq).unwrap().dequantize();
        assert_eq!(y_int.shape(), y_or.shape());
        let diff = y_int.max_abs_diff(&y_or);
        assert!(
            diff <= row.scale * 1.001,
            "case {case} (dw={depthwise} pc={per_channel} k={k} s={stride}): \
             max diff {diff} > one step {}",
            row.scale
        );
    }
}

/// The unfused integer path (i32 accumulate, f32 epilogue) agrees with
/// the f32 conv on identical on-grid operands to float precision.
#[test]
fn prop_int8_unfused_conv_matches_f32() {
    let mut rng = Rng::new(103);
    for case in 0..8u64 {
        let depthwise = case % 2 == 1;
        let (c_in, c_out, groups) =
            if depthwise { (4, 4, 4) } else { (3, 6, 1) };
        let scheme = QScheme::int8_asymmetric();
        let mut w = rand_t(&mut rng, &[c_out, c_in / groups, 3, 3], 0.4);
        let (_, codes) = quantize_weights_retaining(&mut w, &scheme).unwrap();
        let b: Vec<f32> = rng.normal_vec(c_out, 0.2);
        let x = rand_t(&mut rng, &[1, c_in, 8, 8], 1.0);
        let in_qp = params_for_range(x.min(), x.max(), 8, false);
        let xq = QActTensor::quantize(&x, &in_qp);

        let qc = QConv::pack(&codes, &b, 1, 1, groups, &in_qp, None).unwrap();
        let y_int = qc.run_f32(&xq).unwrap();
        let y_f32 =
            conv::conv2d(&xq.dequantize(), &w, Some(&b), 1, 1, groups);
        let rel =
            y_int.max_abs_diff(&y_f32) / y_f32.abs_max().max(1e-6);
        assert!(rel < 1e-4, "case {case}: rel {rel}");
    }
}

/// End-to-end: the packed int8 model matches the fake-quant f32 engine.
/// Every element must be within one step of the final activation grid,
/// modulo at most 1% of elements where an upstream rounding-boundary
/// flip propagates through layer 2 (hard-capped at four steps).
#[test]
fn prop_full_model_int8_parity() {
    for seed in [201u64, 202, 203, 204] {
        let m = testutil::two_layer_model(seed, true);
        let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
        for bc in [BiasCorrMode::None, BiasCorrMode::Analytic] {
            let q = prep
                .quantize(&QScheme::int8_asymmetric(), 8, bc, None)
                .unwrap();
            let qm = q.pack_int8().unwrap();
            assert!(qm.int_layers >= 2, "expected int8 convs: {}", qm.summary());

            let x = testutil::random_input(&m, 2, seed);
            let y_or = nn::forward(&q.model, &x, &q.act_cfg).unwrap();
            let y_int = qm.run(&x).unwrap();
            assert_eq!(y_int.shape(), y_or[0].shape());

            // Per layer the int8 path is within ONE step of the oracle
            // (guaranteed — see prop_int8_conv_matches_fake_quant_oracle).
            // End to end, a rare f32-rounding boundary flip in layer 1
            // can propagate through layer 2's weights, so allow a small
            // fraction of elements one extra step and keep a hard cap.
            let step = q.act_cfg.rows.last().unwrap().scale;
            let mut beyond_one = 0usize;
            for (a, b) in y_int.data().iter().zip(y_or[0].data()) {
                let d = (a - b).abs();
                assert!(
                    d <= 4.0 * step + 1e-6,
                    "seed {seed} {bc:?}: element diff {d} > four steps"
                );
                if d > step * 1.001 {
                    beyond_one += 1;
                }
            }
            let budget = (y_int.len() / 100).max(1);
            assert!(
                beyond_one <= budget,
                "seed {seed} {bc:?}: {beyond_one}/{} elements beyond one \
                 step (budget {budget})",
                y_int.len()
            );
        }
    }
}

/// pack_int8 refuses un-packable configurations with clear errors.
#[test]
fn pack_int8_rejects_bad_configs() {
    let m = testutil::two_layer_model(210, true);
    let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
    // FP32 activations (act_bits = 0) cannot run on the integer path
    let q = prep
        .quantize(&QScheme::int8_asymmetric(), 0, BiasCorrMode::None, None)
        .unwrap();
    let err = q.pack_int8().unwrap_err();
    assert!(format!("{err:#}").contains("quantised"), "got: {err:#}");
    // wide weight grids retain no integer codes
    let q = prep
        .quantize(
            &QScheme::int8_asymmetric().with_bits(16),
            8,
            BiasCorrMode::None,
            None,
        )
        .unwrap();
    assert!(q.int_weights.is_empty());
    assert!(q.pack_int8().is_err());
}
