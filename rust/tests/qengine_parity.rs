//! Property tests for the quantisation grid and the true-int8 execution
//! path: grid invariants, QTensor round-trips, and int8-GEMM vs
//! fake-quant-f32 parity on random layer shapes (dense + depthwise) and
//! on a full DFQ-quantised model.

use dfq::dfq::{quantize_data_free, BiasCorrMode, DfqConfig};
use dfq::dfq::testutil;
use dfq::nn::ops::{clip_act, fake_quant, fake_quant_scalar};
use dfq::nn::qengine::{
    gap_int, plan, AuxGrids, EpiSpec, PlanOpts, QActTensor, QAddInt, QConv,
    QLinear, Scratch,
};
use dfq::nn::{self, conv, ops as fops, SiteCfg};
use dfq::quant::{
    params_for_range, quantize_weights_retaining, QParams, QScheme,
};
use dfq::tensor::{QTensor, Tensor};
use dfq::util::rng::Rng;

fn rand_t(rng: &mut Rng, shape: &[usize], std: f32) -> Tensor {
    Tensor::new(shape, rng.normal_vec(shape.iter().product(), std))
}

/// Asymmetric grids must represent zero exactly (paper §5: zero padding
/// has to be lossless), for any range and bit-width.
#[test]
fn prop_asymmetric_grid_represents_zero_exactly() {
    let mut rng = Rng::new(100);
    for _ in 0..500 {
        let bits = 2 + rng.below(7) as u32;
        let lo = rng.uniform(-8.0, 4.0);
        let hi = rng.uniform(lo + 0.01, lo + 12.0);
        let p = params_for_range(lo, hi, bits, false);
        assert_eq!(
            p.zero_point.fract(),
            0.0,
            "zero point {} not integral for [{lo}, {hi}] @ {bits}b",
            p.zero_point
        );
        let z = fake_quant_scalar(0.0, p.scale, p.zero_point, p.n_levels);
        assert_eq!(z, 0.0, "zero not representable for [{lo}, {hi}] @ {bits}b");
    }
}

/// QTensor pack→unpack round-trip error is ≤ scale/2 per element, for
/// per-tensor and per-channel grids and both storage signednesses.
#[test]
fn prop_qtensor_roundtrip_error_bounded() {
    let mut rng = Rng::new(101);
    for case in 0..64u64 {
        let c_out = 1 + rng.below(6);
        let per = 1 + rng.below(24);
        let mut t = rand_t(&mut rng, &[c_out, per], 1.0);
        for o in 0..c_out {
            // spread channel magnitudes over two decades
            let s = rng.log_uniform(0.05, 5.0);
            t.scale_out_channel(o, s);
        }
        for per_channel in [false, true] {
            for signed in [false, true] {
                let params = if per_channel {
                    t.channel_ranges()
                        .into_iter()
                        .map(|(lo, hi)| params_for_range(lo, hi, 8, false))
                        .collect::<Vec<_>>()
                } else {
                    vec![params_for_range(t.min(), t.max(), 8, false)]
                };
                let q = QTensor::quantize(&t, &params, signed).unwrap();
                let back = q.dequantize();
                for o in 0..c_out {
                    let s = q.param_for_channel(o).scale;
                    for (a, b) in
                        t.out_channel(o).iter().zip(back.out_channel(o))
                    {
                        assert!(
                            (a - b).abs() <= s / 2.0 + 1e-6,
                            "case {case}: err {} > {}",
                            (a - b).abs(),
                            s / 2.0
                        );
                    }
                }
            }
        }
    }
}

/// Build a random quantised conv layer + input and return
/// (packed int conv, quantised input, fake-quant weights, bias, site).
#[allow(clippy::type_complexity, clippy::too_many_arguments)]
fn random_layer(
    rng: &mut Rng,
    c_in: usize,
    c_out: usize,
    k: usize,
    stride: usize,
    pad: usize,
    groups: usize,
    per_channel: bool,
    clip_hi: f32,
) -> (QConv, QActTensor, Tensor, Vec<f32>, SiteCfg) {
    let scheme = if per_channel {
        QScheme::per_channel(8)
    } else {
        QScheme::int8_asymmetric()
    };
    let mut w = rand_t(rng, &[c_out, c_in / groups, k, k], 0.4);
    let (_, codes) = quantize_weights_retaining(&mut w, &scheme).unwrap();
    let b: Vec<f32> = rng.normal_vec(c_out, 0.2);

    let x = rand_t(rng, &[2, c_in, 9, 9], 1.0);
    let in_qp = params_for_range(x.min(), x.max(), 8, false);
    let xq = QActTensor::quantize(&x, &in_qp);

    // output grid from the oracle's pre-activation range (data-free
    // ranges would come from BN stats; any valid grid works here)
    let y = conv::conv2d(&xq.dequantize(), &w, Some(&b), stride, pad, groups);
    let hi = y.max().min(clip_hi).max(0.1);
    let p = params_for_range(0.0, hi, 8, false);
    let row = SiteCfg {
        scale: p.scale,
        zero_point: p.zero_point,
        n_levels: p.n_levels,
        clip_hi,
    };
    let qc = QConv::pack(
        &codes,
        &b,
        stride,
        pad,
        groups,
        &in_qp,
        EpiSpec::Act(&row),
    )
    .unwrap();
    (qc, xq, w, b, row)
}

/// Fused int8 conv (dense + depthwise, random shapes/schemes) matches
/// the fake-quant f32 oracle within ONE quantisation step per element.
#[test]
fn prop_int8_conv_matches_fake_quant_oracle() {
    let mut rng = Rng::new(102);
    for case in 0..24u64 {
        let depthwise = case % 3 == 2;
        let k = [1, 3][rng.below(2)];
        let (c_in, c_out, groups, k) = if depthwise {
            let c = 2 + rng.below(6);
            (c, c, c, 3)
        } else {
            (1 + rng.below(6), 1 + rng.below(8), 1, k)
        };
        let stride = 1 + rng.below(2);
        let pad = k / 2;
        let per_channel = case % 2 == 0;
        let clip_hi = if case % 4 == 0 { 6.0 } else { f32::INFINITY };
        let (qc, xq, w, b, row) = random_layer(
            &mut rng, c_in, c_out, k, stride, pad, groups, per_channel,
            clip_hi,
        );

        // oracle: f32 conv over the SAME on-grid operands, then the
        // engine's clip + fake-quant at the site
        let mut y_or = conv::conv2d(
            &xq.dequantize(),
            &w,
            Some(&b),
            stride,
            pad,
            groups,
        );
        clip_act(&mut y_or, row.clip_hi);
        fake_quant(&mut y_or, row.scale, row.zero_point, row.n_levels);

        let y_int = qc.run_q(&xq).unwrap().dequantize();
        assert_eq!(y_int.shape(), y_or.shape());
        let diff = y_int.max_abs_diff(&y_or);
        assert!(
            diff <= row.scale * 1.001,
            "case {case} (dw={depthwise} pc={per_channel} k={k} s={stride}): \
             max diff {diff} > one step {}",
            row.scale
        );
    }
}

/// The unfused integer path (i32 accumulate, f32 epilogue) agrees with
/// the f32 conv on identical on-grid operands to float precision.
#[test]
fn prop_int8_unfused_conv_matches_f32() {
    let mut rng = Rng::new(103);
    for case in 0..8u64 {
        let depthwise = case % 2 == 1;
        let (c_in, c_out, groups) =
            if depthwise { (4, 4, 4) } else { (3, 6, 1) };
        let scheme = QScheme::int8_asymmetric();
        let mut w = rand_t(&mut rng, &[c_out, c_in / groups, 3, 3], 0.4);
        let (_, codes) = quantize_weights_retaining(&mut w, &scheme).unwrap();
        let b: Vec<f32> = rng.normal_vec(c_out, 0.2);
        let x = rand_t(&mut rng, &[1, c_in, 8, 8], 1.0);
        let in_qp = params_for_range(x.min(), x.max(), 8, false);
        let xq = QActTensor::quantize(&x, &in_qp);

        let qc =
            QConv::pack(&codes, &b, 1, 1, groups, &in_qp, EpiSpec::F32)
                .unwrap();
        let y_int = qc.run_f32(&xq).unwrap();
        let y_f32 =
            conv::conv2d(&xq.dequantize(), &w, Some(&b), 1, 1, groups);
        let rel =
            y_int.max_abs_diff(&y_f32) / y_f32.abs_max().max(1e-6);
        assert!(rel < 1e-4, "case {case}: rel {rel}");
    }
}

/// End-to-end: the packed int8 model matches the fake-quant f32 engine.
/// Every element must be within one step of the final activation grid,
/// modulo at most 1% of elements where an upstream rounding-boundary
/// flip propagates through layer 2 (hard-capped at four steps).
#[test]
fn prop_full_model_int8_parity() {
    for seed in [201u64, 202, 203, 204] {
        let m = testutil::two_layer_model(seed, true);
        let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
        for bc in [BiasCorrMode::None, BiasCorrMode::Analytic] {
            let q = prep
                .quantize(&QScheme::int8_asymmetric(), 8, bc, None)
                .unwrap();
            let qm = q.pack_int8().unwrap();
            assert!(qm.int_layers >= 2, "expected int8 convs: {}", qm.summary());

            let x = testutil::random_input(&m, 2, seed);
            let y_or = nn::forward(&q.model, &x, &q.act_cfg).unwrap();
            let y_int = qm.run(&x).unwrap();
            assert_eq!(y_int.shape(), y_or[0].shape());

            // Per layer the int8 path is within ONE step of the oracle
            // (guaranteed — see prop_int8_conv_matches_fake_quant_oracle).
            // End to end, a rare f32-rounding boundary flip in layer 1
            // can propagate through layer 2's weights, so allow a small
            // fraction of elements one extra step and keep a hard cap.
            let step = q.act_cfg.rows.last().unwrap().scale;
            let mut beyond_one = 0usize;
            for (a, b) in y_int.data().iter().zip(y_or[0].data()) {
                let d = (a - b).abs();
                assert!(
                    d <= 4.0 * step + 1e-6,
                    "seed {seed} {bc:?}: element diff {d} > four steps"
                );
                if d > step * 1.001 {
                    beyond_one += 1;
                }
            }
            let budget = (y_int.len() / 100).max(1);
            assert!(
                beyond_one <= budget,
                "seed {seed} {bc:?}: {beyond_one}/{} elements beyond one \
                 step (budget {budget})",
                y_int.len()
            );
        }
    }
}

/// Random activation grid covering `[lo, hi]`.
fn rand_grid(rng: &mut Rng, lo: f32, hi: f32) -> QParams {
    let a = rng.uniform(lo, (lo + hi) / 2.0);
    let b = rng.uniform(a + 0.05, hi);
    params_for_range(a, b, 8, false)
}

/// Random codes on a grid, wrapped as a feature map.
fn rand_codes(rng: &mut Rng, shape: &[usize], qp: QParams) -> QActTensor {
    let n: usize = shape.iter().product();
    let hi = qp.n_levels as usize;
    let codes = (0..n).map(|_| rng.below(hi) as u8).collect();
    QActTensor { shape: shape.to_vec(), codes, qp }
}

/// Requantise-add matches the oracle (f32 add of the dequantised inputs,
/// fake-quantised onto the output grid) within ONE step of the output
/// grid, across random input/output grids.
#[test]
fn prop_requantize_add_matches_oracle() {
    let mut rng = Rng::new(301);
    for case in 0..64u64 {
        let qa = rand_grid(&mut rng, -4.0, 4.0);
        let qb = rand_grid(&mut rng, -2.0, 6.0);
        let qo = rand_grid(&mut rng, -6.0, 10.0);
        let a = rand_codes(&mut rng, &[2, 3, 4, 4], qa);
        let b = rand_codes(&mut rng, &[2, 3, 4, 4], qb);
        let add = QAddInt::pack(&qa, &qb, &qo).unwrap();
        let got = add.run(&a, &b).unwrap();
        assert_eq!(got.qp, qo);

        let mut want = fops::add(&a.dequantize(), &b.dequantize());
        fake_quant(&mut want, qo.scale, qo.zero_point, qo.n_levels);
        let diff = got.dequantize().max_abs_diff(&want);
        assert!(
            diff <= qo.scale * 1.001,
            "case {case}: requantise-add off by {diff} (> one step {})",
            qo.scale
        );
    }
}

/// Integer GAP matches the oracle (f32 mean of the dequantised values)
/// within ONE step of the input grid — and stays on that grid.
#[test]
fn prop_integer_gap_matches_oracle() {
    let mut rng = Rng::new(302);
    for case in 0..32u64 {
        let qp = rand_grid(&mut rng, -3.0, 5.0);
        let h = 1 + rng.below(7);
        let w = 1 + rng.below(7);
        let x = rand_codes(&mut rng, &[2, 4, h, w], qp);
        let got = gap_int(&x).unwrap();
        assert_eq!(got.shape, vec![2, 4]);
        assert_eq!(got.qp, qp);
        let want = fops::global_avg_pool(&x.dequantize());
        let diff = got.dequantize().max_abs_diff(&want);
        assert!(
            diff <= qp.scale * 0.5 + 1e-5,
            "case {case} ({h}x{w}): gap off by {diff} (> half step {})",
            qp.scale * 0.5
        );
    }
}

/// The int8 linear head (integer GEMM + exact f32 epilogue) matches the
/// oracle linear over identical on-grid operands to float precision —
/// far inside the one-step-per-op budget.
#[test]
fn prop_int8_linear_head_matches_oracle() {
    let mut rng = Rng::new(303);
    for case in 0..16u64 {
        let per_channel = case % 2 == 0;
        let scheme = if per_channel {
            QScheme::per_channel(8)
        } else {
            QScheme::int8_asymmetric()
        };
        let (out_dim, in_dim) = (1 + rng.below(10), 1 + rng.below(24));
        let mut w = rand_t(&mut rng, &[out_dim, in_dim], 0.4);
        let (_, codes) = quantize_weights_retaining(&mut w, &scheme).unwrap();
        let b: Vec<f32> = rng.normal_vec(out_dim, 0.2);
        let x = rand_t(&mut rng, &[3, in_dim], 1.0);
        let in_qp = params_for_range(x.min(), x.max(), 8, false);
        let xq = QActTensor::quantize(&x, &in_qp);

        let ql = QLinear::pack(&codes, &b, &in_qp).unwrap();
        let got = ql.run(&xq, &mut Scratch::new()).unwrap();
        let want = fops::linear(&xq.dequantize(), &w, &b);
        assert_eq!(got.shape(), want.shape());
        let rel = got.max_abs_diff(&want) / want.abs_max().max(1e-6);
        assert!(
            rel < 1e-4,
            "case {case} (pc={per_channel} {out_dim}x{in_dim}): rel {rel}"
        );
    }
}

/// Standalone activation requantisation (e.g. a ReLU after a residual
/// add) matches clip + fake-quant within one step of the site grid.
#[test]
fn prop_requantizer_matches_clip_fake_quant() {
    use dfq::nn::qengine::Requantizer;
    let mut rng = Rng::new(304);
    for case in 0..32u64 {
        let in_qp = rand_grid(&mut rng, -4.0, 6.0);
        let clip_hi = if case % 2 == 0 { 6.0 } else { f32::INFINITY };
        let p = params_for_range(0.0, rng.uniform(0.5, 8.0), 8, false);
        let row = SiteCfg {
            scale: p.scale,
            zero_point: p.zero_point,
            n_levels: p.n_levels,
            clip_hi,
        };
        let x = rand_codes(&mut rng, &[1, 2, 5, 5], in_qp);
        let rq = Requantizer::pack(&in_qp, &row).unwrap();
        let got = rq.run(&x).unwrap();
        let mut want = x.dequantize();
        clip_act(&mut want, row.clip_hi);
        fake_quant(&mut want, row.scale, row.zero_point, row.n_levels);
        let diff = got.dequantize().max_abs_diff(&want);
        assert!(
            diff <= row.scale * 1.001,
            "case {case}: requantizer off by {diff} (> one step {})",
            row.scale
        );
    }
}

/// End-to-end: the MobileNet-style residual-block model (dense conv +
/// depthwise + residual add + GAP + linear head) plans with ZERO f32
/// fallback ops and matches the fake-quant oracle within the propagated
/// per-op step budget.
#[test]
fn residual_block_plans_fully_integer_and_matches_oracle() {
    for seed in [401u64, 402, 403] {
        let m = testutil::residual_block_model(seed);
        let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
        let q = prep
            .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::None, None)
            .unwrap();
        let qm = q.pack_int8().unwrap();

        // the acceptance bar: nothing dequantises mid-network
        assert_eq!(qm.f32_layers, 0, "seed {seed}: {}", qm.summary());
        assert_eq!(qm.fallback_ops(), 0, "seed {seed}: {}", qm.summary());
        assert_eq!(qm.int_layers, 4, "seed {seed}: {}", qm.summary());
        // strict planning accepts the same model
        q.pack_int8_opts(PlanOpts { int8_only: true, ..Default::default() }).unwrap();
        let report = qm.summarize();
        for needle in
            ["add-requant [int8]", "gap [int8]", "linear [int8->f32]"]
        {
            assert!(report.contains(needle), "missing '{needle}' in\n{report}");
        }
        assert!(!report.contains("FALLBACK"), "{report}");

        let x = testutil::random_input(&m, 2, seed);
        let y_or = nn::forward(&q.model, &x, &q.act_cfg).unwrap();
        let y_int = qm.run(&x).unwrap();
        assert_eq!(y_int.shape(), y_or[0].shape());

        // Propagated error budget: each integer op is within one step of
        // the oracle on identical inputs; upstream code diffs amplify
        // through a layer by at most its max row L1 norm.
        let layers = q.model.layers();
        let l1_of = |i: usize| -> f32 {
            let w = match &layers[i].op {
                dfq::graph::Op::Conv { w, .. }
                | dfq::graph::Op::Linear { w, .. } => {
                    q.model.tensor(w).unwrap()
                }
                _ => unreachable!(),
            };
            (0..w.shape()[0])
                .map(|o| w.out_channel(o).iter().map(|v| v.abs()).sum())
                .fold(0f32, f32::max)
        };
        let (amp_dw, amp_pw, amp_head) = (l1_of(1), l1_of(2), l1_of(3));
        let s1 = q.act_cfg.rows[1].scale; // first ReLU site
        let s2 = q.act_cfg.rows[2].scale; // second ReLU site
        let s_add = q.act_cfg.rows[3].scale; // add site
        let s_pre = q
            .preact_params
            .iter()
            .find(|(id, _)| *id == layers[2].id)
            .map(|(_, p)| p.scale)
            .expect("pointwise conv has a pre-activation grid");
        let e_a1 = s1;
        let e_a2 = e_a1 * amp_dw + s2;
        let e_p3 = e_a2 * amp_pw + s_pre;
        let e_add = e_a1 + e_p3 + s_add;
        let e_gap = e_add + 0.5 * s_add;
        let tol = 1.5 * (e_gap * amp_head) + 1e-3;
        let diff = y_int.max_abs_diff(&y_or[0]);
        assert!(
            diff <= tol,
            "seed {seed}: end-to-end diff {diff} > budget {tol} \
             (amps {amp_dw}/{amp_pw}/{amp_head})"
        );
    }
}

/// End-to-end acceptance for the branchy-graph ops: the inception-style
/// fixture (max-pool stem, avg-pool branch, multi-branch concat) plans
/// with ZERO f32 fallback ops — including under `int8_only` — and
/// matches the fake-quant oracle within the propagated per-op budget.
#[test]
fn inception_block_plans_fully_integer_and_matches_oracle() {
    for seed in [501u64, 502, 503] {
        let m = testutil::inception_block_model(seed);
        let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
        let q = prep
            .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::None, None)
            .unwrap();
        // the acceptance bar: the branchy graph stays integer end to end
        let qm = q.pack_int8_opts(PlanOpts { int8_only: true, ..Default::default() }).unwrap();
        assert_eq!(qm.fallback_ops(), 0, "seed {seed}: {}", qm.summary());
        assert_eq!(qm.f32_layers, 0, "seed {seed}: {}", qm.summary());
        assert_eq!(qm.int_layers, 6, "seed {seed}: {}", qm.summary());
        let report = qm.summarize();
        for needle in [
            "pool-max [int8]",
            "pool-avg [int8]",
            "concat-requant [int8]",
            "gap [int8]",
            "linear [int8->f32]",
        ] {
            assert!(report.contains(needle), "missing '{needle}' in\n{report}");
        }
        assert!(!report.contains("FALLBACK"), "{report}");

        let x = testutil::random_input(&m, 2, seed);
        let y_or = nn::forward(&q.model, &x, &q.act_cfg).unwrap();
        let y_int = qm.run(&x).unwrap();
        assert_eq!(y_int.shape(), y_or[0].shape());

        // Propagated budget. Per op the int path is within one step of
        // the oracle on identical inputs (max-pool is exact, avg-pool and
        // GAP add half a step of their input grid); a conv amplifies an
        // upstream diff by at most its max row L1 norm and adds one step
        // of its fused site.
        let layers = q.model.layers();
        let l1_of = |i: usize| -> f32 {
            let w = match &layers[i].op {
                dfq::graph::Op::Conv { w, .. }
                | dfq::graph::Op::Linear { w, .. } => {
                    q.model.tensor(w).unwrap()
                }
                _ => unreachable!(),
            };
            (0..w.shape()[0])
                .map(|o| w.out_channel(o).iter().map(|v| v.abs()).sum())
                .fold(0f32, f32::max)
        };
        // layers in node order: stem, branch-a, b1, b2, branch-c, head
        let (amp_a, amp_b1, amp_b2, amp_c, amp_head) =
            (l1_of(1), l1_of(2), l1_of(3), l1_of(4), l1_of(5));
        // sites in node order: input, stem act, a act, b1 act, b2 act,
        // c act, concat
        let s_stem = q.act_cfg.rows[1].scale;
        let s_a = q.act_cfg.rows[2].scale;
        let s_b1 = q.act_cfg.rows[3].scale;
        let s_b2 = q.act_cfg.rows[4].scale;
        let s_c = q.act_cfg.rows[5].scale;
        let s_cat = q.act_cfg.rows[6].scale;
        let e_stem = s_stem; // max-pool is exact: no extra error
        let e_a = e_stem * amp_a + s_a;
        let e_b = (e_stem * amp_b1 + s_b1) * amp_b2 + s_b2;
        let e_c = (e_stem + 0.5 * s_stem) * amp_c + s_c; // avg-pool + ½ step
        let e_cat = e_a.max(e_b).max(e_c) + s_cat;
        let e_gap = e_cat + 0.5 * s_cat;
        let tol = 1.5 * (e_gap * amp_head) + 1e-3;
        let diff = y_int.max_abs_diff(&y_or[0]);
        assert!(
            diff <= tol,
            "seed {seed}: end-to-end diff {diff} > budget {tol}"
        );
    }
}

/// Generic propagated per-op error budget for a quantised fixture: the
/// same recurrence the residual/inception tests derive by hand, walked
/// over the node list so branchier graphs don't need bespoke algebra.
/// Per op the integer path is within one step of the oracle on identical
/// inputs (max-pool exact; avg-pool/GAP add half a step of their input
/// grid); a conv amplifies an upstream diff by at most its max row L1
/// norm; add sums branch errors, concat takes the worst branch.
fn propagated_budget(q: &dfq::dfq::QuantizedModel) -> f32 {
    use dfq::graph::{Op, PoolKind};
    use std::collections::HashMap;
    let m = &q.model;
    // Act/Add/Concat nodes map to activation-site rows in node order
    // (row 0 is the input site)
    let mut site_scale: HashMap<usize, f32> = HashMap::new();
    let mut row = 1usize;
    for n in &m.nodes {
        if matches!(n.op, Op::Act(_) | Op::Add | Op::Concat) {
            site_scale.insert(n.id, q.act_cfg.rows[row].scale);
            row += 1;
        }
    }
    let l1_of = |w: &str| -> f32 {
        let t = m.tensor(w).unwrap();
        (0..t.shape()[0])
            .map(|o| t.out_channel(o).iter().map(|v| v.abs()).sum())
            .fold(0f32, f32::max)
    };
    // e: accumulated diff vs the oracle at each node's output;
    // g: scale of the grid that output lives on (for the half-step
    // rounding of averaging ops)
    let mut e: HashMap<usize, f32> = HashMap::new();
    let mut g: HashMap<usize, f32> = HashMap::new();
    let mut tol = 0f32;
    for n in &m.nodes {
        let (en, gn) = match &n.op {
            Op::Input => (0.0, q.act_cfg.rows[0].scale),
            Op::Conv { w, .. } | Op::ConvT2d { w, .. } => {
                let a = e[&n.inputs[0]] * l1_of(w);
                let fused = m.nodes.iter().any(|c| {
                    matches!(c.op, Op::Act(_))
                        && c.inputs.first() == Some(&n.id)
                });
                if fused {
                    // the following act site contributes the step
                    (a, 0.0)
                } else {
                    let s_pre = q
                        .preact_params
                        .iter()
                        .find(|(id, _)| *id == n.id)
                        .map(|(_, p)| p.scale)
                        .unwrap_or(0.0);
                    (a + s_pre, s_pre)
                }
            }
            Op::Act(_) => {
                let s = site_scale[&n.id];
                (e[&n.inputs[0]] + s, s)
            }
            Op::Pool2d { kind, .. } => {
                let (ein, gin) = (e[&n.inputs[0]], g[&n.inputs[0]]);
                match kind {
                    PoolKind::Max => (ein, gin),
                    PoolKind::Avg => (ein + 0.5 * gin, gin),
                }
            }
            Op::Upsample { .. } => (e[&n.inputs[0]], g[&n.inputs[0]]),
            Op::Concat => {
                let s = site_scale[&n.id];
                let worst = n
                    .inputs
                    .iter()
                    .map(|i| e[i])
                    .fold(0f32, f32::max);
                (worst + s, s)
            }
            Op::Add => {
                let s = site_scale[&n.id];
                (n.inputs.iter().map(|i| e[i]).sum::<f32>() + s, s)
            }
            Op::Gap => {
                (e[&n.inputs[0]] + 0.5 * g[&n.inputs[0]], g[&n.inputs[0]])
            }
            Op::Linear { w, .. } => {
                // f32 logits are float-exact given their inputs
                tol = tol.max(1.5 * e[&n.inputs[0]] * l1_of(w) + 1e-3);
                (0.0, 0.0)
            }
            Op::BatchNorm { .. } => unreachable!("budget wants a folded model"),
        };
        e.insert(n.id, en);
        g.insert(n.id, gn);
    }
    tol
}

/// End-to-end acceptance for the segmentation decoder ops: the
/// DeepLab-style fixture (max-pool stem inside a CLE pair, global-pool
/// ASPP branch + upsample, concat merge, transposed-conv decoder) plans
/// with ZERO f32 fallback ops under `int8_only`, matches the fake-quant
/// oracle within the propagated per-op budget, and runs bitwise
/// identically under forced-scalar dispatch.
#[test]
fn deeplab_head_plans_fully_integer_and_matches_oracle() {
    for seed in [601u64, 602, 603] {
        let m = testutil::deeplab_head_model(seed);
        let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
        let q = prep
            .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::None, None)
            .unwrap();
        let qm = q
            .pack_int8_opts(PlanOpts { int8_only: true, ..Default::default() })
            .unwrap();
        assert_eq!(qm.fallback_ops(), 0, "seed {seed}: {}", qm.summary());
        assert_eq!(qm.f32_layers, 0, "seed {seed}: {}", qm.summary());
        // 7 convs (2 stem + 3 branch + convT decoder + head) + linear
        assert_eq!(qm.int_layers, 8, "seed {seed}: {}", qm.summary());
        let report = qm.summarize();
        for needle in [
            "convT [int8]",
            "pool-max [int8]",
            "pool-avg-global [int8]",
            "concat-requant [int8]",
            "gap [int8]",
            "linear [int8->f32]",
        ] {
            assert!(report.contains(needle), "missing '{needle}' in\n{report}");
        }
        assert!(!report.contains("FALLBACK"), "{report}");

        let x = testutil::random_input(&m, 2, seed);
        let y_or = nn::forward(&q.model, &x, &q.act_cfg).unwrap();
        let y_int = qm.run(&x).unwrap();
        assert_eq!(y_int.shape(), y_or[0].shape());
        let tol = propagated_budget(&q);
        let diff = y_int.max_abs_diff(&y_or[0]);
        assert!(
            diff <= tol,
            "seed {seed}: end-to-end diff {diff} > budget {tol}"
        );

        let scalar = q
            .pack_int8_opts(PlanOpts {
                int8_only: true,
                force_scalar: true,
                ..Default::default()
            })
            .unwrap();
        assert_eq!(
            y_int.data(),
            scalar.run(&x).unwrap().data(),
            "seed {seed}: native dispatch drifted from scalar"
        );
    }
}

/// End-to-end acceptance for the detection-head ops: the SSD-style
/// fixture (rectangular max-pool pyramid, global max *and* avg pools
/// onto a shared 1x1 grid, concat merge) plans with ZERO f32 fallback
/// ops under `int8_only`, matches the oracle within the propagated
/// budget, and is bitwise-stable under forced-scalar dispatch.
#[test]
fn ssd_head_plans_fully_integer_and_matches_oracle() {
    for seed in [701u64, 702, 703] {
        let m = testutil::ssd_head_model(seed);
        let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
        let q = prep
            .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::None, None)
            .unwrap();
        let qm = q
            .pack_int8_opts(PlanOpts { int8_only: true, ..Default::default() })
            .unwrap();
        assert_eq!(qm.fallback_ops(), 0, "seed {seed}: {}", qm.summary());
        assert_eq!(qm.f32_layers, 0, "seed {seed}: {}", qm.summary());
        // 5 convs (stem + 3 per-scale heads + merge) + linear
        assert_eq!(qm.int_layers, 6, "seed {seed}: {}", qm.summary());
        let report = qm.summarize();
        for needle in [
            "pool-max [int8]",
            "pool-max-global [int8]",
            "pool-avg-global [int8]",
            "concat-requant [int8]",
            "gap [int8]",
            "linear [int8->f32]",
        ] {
            assert!(report.contains(needle), "missing '{needle}' in\n{report}");
        }
        assert!(!report.contains("FALLBACK"), "{report}");

        let x = testutil::random_input(&m, 2, seed);
        let y_or = nn::forward(&q.model, &x, &q.act_cfg).unwrap();
        let y_int = qm.run(&x).unwrap();
        assert_eq!(y_int.shape(), y_or[0].shape());
        let tol = propagated_budget(&q);
        let diff = y_int.max_abs_diff(&y_or[0]);
        assert!(
            diff <= tol,
            "seed {seed}: end-to-end diff {diff} > budget {tol}"
        );

        let scalar = q
            .pack_int8_opts(PlanOpts {
                int8_only: true,
                force_scalar: true,
                ..Default::default()
            })
            .unwrap();
        assert_eq!(
            y_int.data(),
            scalar.run(&x).unwrap().data(),
            "seed {seed}: native dispatch drifted from scalar"
        );
    }
}

/// Batch-parallel `run_all` over the branchy fixture stays bitwise equal
/// to the serial path (concat/pool kernels are image-independent too).
#[test]
fn inception_batch_parallel_is_bitwise_identical() {
    let m = testutil::inception_block_model(510);
    let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
    let q = prep
        .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::None, None)
        .unwrap();
    let qm = q.pack_int8().unwrap();
    let x = testutil::random_input(&m, 5, 511);
    let par = qm.run_all(&x).unwrap();
    let ser = qm.run_batch(&x).unwrap();
    for (a, b) in par.iter().zip(&ser) {
        assert_eq!(a.data(), b.data(), "parallel path diverged bitwise");
    }
}

/// Batch-parallel `run_all` is bitwise-identical to the serial
/// whole-batch path (every kernel is image-independent).
#[test]
fn batch_parallel_run_all_is_bitwise_identical() {
    let m = testutil::residual_block_model(410);
    let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
    let q = prep
        .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::None, None)
        .unwrap();
    let qm = q.pack_int8().unwrap();
    let x = testutil::random_input(&m, 5, 411);
    let par = qm.run_all(&x).unwrap();
    let ser = qm.run_batch(&x).unwrap();
    assert_eq!(par.len(), ser.len());
    for (a, b) in par.iter().zip(&ser) {
        assert_eq!(a.shape(), b.shape());
        assert_eq!(a.data(), b.data(), "parallel path diverged bitwise");
    }
}

/// Without aux pre-activation grids the residual branch must fall back —
/// visible in the plan report, counted, and fatal under `int8_only`.
#[test]
fn int8_only_rejects_surviving_fallbacks() {
    let m = testutil::residual_block_model(420);
    let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
    let q = prep
        .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::None, None)
        .unwrap();
    // planning WITHOUT the pre-activation grids: the pointwise conv
    // cannot requantise, so the residual add falls back to f32
    let loose = plan(
        &q.model,
        &q.int_weights,
        &q.act_cfg,
        &AuxGrids::empty(),
        PlanOpts::default(),
    )
    .unwrap();
    assert!(loose.fallback_ops() >= 1, "{}", loose.summary());
    assert!(loose.summarize().contains("FALLBACK"), "{}", loose.summarize());
    let err = plan(
        &q.model,
        &q.int_weights,
        &q.act_cfg,
        &AuxGrids::empty(),
        PlanOpts { int8_only: true, ..Default::default() },
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("fallback"), "got: {msg}");
}

/// pack_int8 refuses un-packable configurations with clear errors.
#[test]
fn pack_int8_rejects_bad_configs() {
    let m = testutil::two_layer_model(210, true);
    let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
    // FP32 activations (act_bits = 0) cannot run on the integer path
    let q = prep
        .quantize(&QScheme::int8_asymmetric(), 0, BiasCorrMode::None, None)
        .unwrap();
    let err = q.pack_int8().unwrap_err();
    assert!(format!("{err:#}").contains("quantised"), "got: {err:#}");
    // wide weight grids retain no integer codes
    let q = prep
        .quantize(
            &QScheme::int8_asymmetric().with_bits(16),
            8,
            BiasCorrMode::None,
            None,
        )
        .unwrap();
    assert!(q.int_weights.is_empty());
    assert!(q.pack_int8().is_err());
}

/// Every GEMM kernel the host can run is bitwise-identical to the
/// scalar oracle on random shapes — remainder tails on every axis
/// (m % 4 rows, n % 16 columns, odd k depths), planted zero rows for
/// the zero-skip path, and saturation-extreme operands (255 × −128)
/// that would overflow an i16-saturating inner product.
#[test]
fn dispatch_gemm_kinds_match_scalar_oracle_on_random_shapes() {
    use dfq::nn::qengine::{
        available_kinds, qgemm_into_kind, qgemm_into_scalar,
    };
    let mut rng = Rng::new(700);
    for case in 0..48u64 {
        let m = 1 + rng.below(21);
        let k = 1 + rng.below(70);
        let n = 1 + rng.below(40);
        let mut a: Vec<u8> =
            (0..m * k).map(|_| rng.below(256) as u8).collect();
        let b: Vec<i8> =
            (0..k * n).map(|_| rng.below(256) as u8 as i8).collect();
        if case % 3 == 0 {
            // worst-case magnitudes: any kernel accumulating u8·i8
            // pair-products in fewer than 17 signed bits would saturate
            for v in a.iter_mut().take(k) {
                *v = 255;
            }
        }
        for v in a.iter_mut() {
            if rng.below(5) == 0 {
                *v = 0;
            }
        }
        let mut want = vec![0i32; m * n];
        qgemm_into_scalar(&a, &b, m, k, n, &mut want);
        for kind in available_kinds() {
            let mut got = vec![-1i32; m * n];
            qgemm_into_kind(kind, &a, &b, m, k, n, &mut got);
            assert_eq!(
                got, want,
                "case {case}: kind {kind:?} diverged at m={m} k={k} n={n}"
            );
        }
    }
}

/// Every dispatch target produces bitwise-identical conv outputs to the
/// scalar reference across all epilogue variants (`F32`, `Act`, `Grid`),
/// per-channel and per-tensor weight grids, dense and depthwise layers —
/// shapes chosen to hit the GEMM remainder tails (c_out % 16,
/// spatial % 4, odd reduction depths).
#[test]
fn dispatch_conv_kinds_are_bitwise_identical_across_epilogues() {
    use dfq::nn::qengine::{available_kinds, KernelKind};
    let mut rng = Rng::new(720);
    // (c_in, c_out, k, stride, pad, groups)
    let shapes = [
        (3usize, 8usize, 3usize, 1usize, 1usize, 1usize),
        (5, 17, 1, 1, 0, 1),  // n-tail: 17 = 16 + 1
        (2, 5, 3, 2, 1, 1),   // strided, odd reduction depth
        (7, 16, 3, 1, 1, 1),  // exact panel width, odd depth
        (6, 6, 3, 1, 1, 6),   // depthwise 3×3
        (10, 10, 5, 1, 2, 10), // depthwise 5×5
    ];
    for (case, &(c_in, c_out, k, stride, pad, groups)) in
        shapes.iter().enumerate()
    {
        for per_channel in [false, true] {
            let scheme = if per_channel {
                QScheme::per_channel(8)
            } else {
                QScheme::int8_asymmetric()
            };
            let mut w = rand_t(&mut rng, &[c_out, c_in / groups, k, k], 0.4);
            let (_, codes) =
                quantize_weights_retaining(&mut w, &scheme).unwrap();
            let b: Vec<f32> = rng.normal_vec(c_out, 0.2);
            let x = rand_t(&mut rng, &[2, c_in, 9, 11], 1.0);
            let in_qp = params_for_range(x.min(), x.max(), 8, false);
            let xq = QActTensor::quantize(&x, &in_qp);
            let site = SiteCfg {
                scale: 0.04,
                zero_point: 5.0,
                n_levels: 256.0,
                clip_hi: 6.0,
            };
            let grid = params_for_range(-1.0, 3.0, 8, false);
            for epi_tag in 0..3 {
                let epi = match epi_tag {
                    0 => EpiSpec::F32,
                    1 => EpiSpec::Act(&site),
                    _ => EpiSpec::Grid(grid),
                };
                let native =
                    QConv::pack(&codes, &b, stride, pad, groups, &in_qp, epi)
                        .unwrap();
                let mut scalar = native.clone();
                scalar.set_kernel(KernelKind::Scalar);
                for kind in available_kinds() {
                    let mut qc = native.clone();
                    qc.set_kernel(kind);
                    assert_eq!(qc.kernel_kind(), kind);
                    if epi_tag == 0 {
                        let got = qc.run_f32(&xq).unwrap();
                        let want = scalar.run_f32(&xq).unwrap();
                        assert_eq!(
                            got.data(),
                            want.data(),
                            "case {case} pc={per_channel} F32 epi: \
                             kind {kind:?} diverged"
                        );
                    } else {
                        let got = qc.run_q(&xq).unwrap();
                        let want = scalar.run_q(&xq).unwrap();
                        assert_eq!(
                            got.codes, want.codes,
                            "case {case} pc={per_channel} epi {epi_tag}: \
                             kind {kind:?} diverged"
                        );
                    }
                }
            }
        }
    }
}

/// The int8 linear head is bitwise-identical under every dispatch target
/// (logits are f32 but computed from the same i32 accumulators, so
/// equality is exact).
#[test]
fn dispatch_linear_kinds_are_bitwise_identical() {
    use dfq::nn::qengine::{available_kinds, KernelKind};
    let mut rng = Rng::new(730);
    for &(in_dim, out_dim) in &[(32usize, 16usize), (19, 17), (7, 1), (65, 40)]
    {
        let mut w = rand_t(&mut rng, &[out_dim, in_dim], 0.4);
        let (_, codes) =
            quantize_weights_retaining(&mut w, &QScheme::per_channel(8))
                .unwrap();
        let b: Vec<f32> = rng.normal_vec(out_dim, 0.2);
        let in_qp = params_for_range(-2.0, 2.0, 8, false);
        let x = QActTensor {
            shape: vec![3, in_dim],
            codes: (0..3 * in_dim).map(|_| rng.below(256) as u8).collect(),
            qp: in_qp,
        };
        let native = QLinear::pack(&codes, &b, &in_qp).unwrap();
        let mut scalar = native.clone();
        scalar.set_kernel(KernelKind::Scalar);
        let want = scalar.run(&x, &mut Scratch::new()).unwrap();
        for kind in available_kinds() {
            let mut lin = native.clone();
            lin.set_kernel(kind);
            let got = lin.run(&x, &mut Scratch::new()).unwrap();
            assert_eq!(
                got.data(),
                want.data(),
                "linear ({in_dim}->{out_dim}): kind {kind:?} diverged"
            );
        }
    }
}

/// End-to-end dispatch parity: the residual and inception fixtures run
/// bitwise-identically under a forced-scalar plan and the host's native
/// dispatch — the SIMD microkernels change *nothing* but wall-clock.
#[test]
fn force_scalar_plan_is_bitwise_identical_end_to_end() {
    for (branchy, seed) in [(false, 440u64), (true, 540)] {
        let m = if branchy {
            testutil::inception_block_model(seed)
        } else {
            testutil::residual_block_model(seed)
        };
        let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
        let q = prep
            .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::None, None)
            .unwrap();
        let native = q
            .pack_int8_opts(PlanOpts { int8_only: true, ..Default::default() })
            .unwrap();
        let scalar = q
            .pack_int8_opts(PlanOpts {
                int8_only: true,
                force_scalar: true,
                ..Default::default()
            })
            .unwrap();
        let x = testutil::random_input(&m, 3, seed);
        let y_native = native.run(&x).unwrap();
        let y_scalar = scalar.run(&x).unwrap();
        assert_eq!(
            y_native.data(),
            y_scalar.data(),
            "branchy={branchy} seed {seed}: native dispatch drifted from \
             the scalar reference"
        );
    }
}

/// Observability acceptance: a profiling-enabled plan must be
/// *bitwise-invisible* in its outputs — identical logits to the plain
/// plan on both the residual and inception fixtures — while the
/// accumulated [`RunProfile`] itself stays self-consistent (every op
/// called once per run, per-op seconds bounded by the whole-pass wall
/// time, GEMM calls matching the static per-call counts).
#[test]
fn profiled_plan_is_bitwise_invisible_and_self_consistent() {
    use dfq::nn::qengine::PlanOpts;

    for (branchy, seed) in [(false, 441u64), (true, 541)] {
        let m = if branchy {
            testutil::inception_block_model(seed)
        } else {
            testutil::residual_block_model(seed)
        };
        let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
        let q = prep
            .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::None, None)
            .unwrap();
        let plain = q
            .pack_int8_opts(PlanOpts { int8_only: true, ..Default::default() })
            .unwrap();
        let profiled = q
            .pack_int8_opts(PlanOpts {
                int8_only: true,
                profile: true,
                ..Default::default()
            })
            .unwrap();
        assert!(!plain.profiling());
        assert!(profiled.profiling());
        assert!(plain.profile().is_none());

        let x = testutil::random_input(&m, 3, seed);
        let runs = 3usize;
        let mut y_plain = Vec::new();
        let mut y_prof = Vec::new();
        for _ in 0..runs {
            y_plain.push(plain.run(&x).unwrap());
            y_prof.push(profiled.run(&x).unwrap());
        }
        for (a, b) in y_plain.iter().zip(&y_prof) {
            let bits_a: Vec<u32> =
                a.data().iter().map(|v| v.to_bits()).collect();
            let bits_b: Vec<u32> =
                b.data().iter().map(|v| v.to_bits()).collect();
            assert_eq!(
                bits_a, bits_b,
                "branchy={branchy}: profiling changed the logits"
            );
        }

        let prof = profiled.profile().unwrap();
        assert_eq!(prof.ops.len(), profiled.num_ops());
        // the batch of 3 may split into per-image parallel passes, so
        // per-op calls count *images x runs* up to the worker split; the
        // invariant that holds either way is equal calls on every op
        let calls = prof.ops[0].calls;
        assert!(calls > 0, "no calls accumulated");
        for o in &prof.ops {
            assert_eq!(
                o.calls, calls,
                "op {} ({}) called unevenly",
                o.node, o.label
            );
            assert_eq!(o.gemm_calls, o.gemm_per_call * o.calls);
            assert!(o.secs >= 0.0 && o.secs.is_finite());
            assert!(o.bytes > 0, "op {} moved no bytes", o.node);
        }
        assert!(
            prof.secs() <= prof.total_secs + 1e-9,
            "per-op sum {} exceeds whole-pass wall time {}",
            prof.secs(),
            prof.total_secs
        );
        assert!(prof.runs > 0);

        // reset zeroes the accumulation but keeps profiling on
        profiled.reset_profile();
        let zeroed = profiled.profile().unwrap();
        assert_eq!(zeroed.runs, 0);
        assert!(zeroed.ops.iter().all(|o| o.calls == 0 && o.secs == 0.0));

        // the rendered table stays in sync with the op count
        let table = prof.table();
        assert_eq!(table.lines().count(), prof.ops.len() + 2);
    }
}
