//! Sharded-ingress integration tests: the exactly-once answering
//! property under random interleavings of shed-inducing bursts, hot
//! swaps and evictions, and the per-lane metrics merge invariant
//! (lane views sum to the shared per-variant view, which matches a
//! single-lane baseline on the same workload). Companion to
//! `tests/serve_lifecycle.rs` (registry lifecycle) — this file covers
//! the admission/lane layer underneath it.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use dfq::dfq::{
    quantize_data_free, testutil, BiasCorrMode, DfqConfig, QuantizedModel,
};
use dfq::nn::qengine::PlanOpts;
use dfq::quant::QScheme;
use dfq::serve::registry::VARIANT_INT8;
use dfq::serve::{
    BatchExecutor, Priority, QuantExecutor, Registry, ServeConfig, Server,
    SubmitError,
};
use dfq::util::rng::Rng;

fn quantized(seed: u64) -> QuantizedModel {
    let m = testutil::two_layer_model(seed, true);
    let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
    prep.quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::None, None)
        .unwrap()
}

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("dfq-ingress-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// The exactly-once property: under a random interleaving of
/// over-capacity bursts, hot swaps and evict/re-load cycles, every
/// submitted request is either answered exactly once or rejected
/// exactly once with the typed shed error — nothing vanishes, nothing
/// double-fires, and the shed path actually triggers.
#[test]
fn random_shed_swap_evict_interleavings_answer_every_request_once() {
    let dir = temp_dir("exactly-once");
    let path = dir.join("m.dfqm");
    let qa = quantized(71);
    let qb = quantized(72); // same arch, different weights (swap target)
    qa.save_artifact(&path, PlanOpts::default()).unwrap();
    let x = testutil::random_input(&qa.model, 1, 3);

    let mut reg = Registry::new(ServeConfig {
        max_batch: 4,
        max_delay: Duration::from_millis(1),
        queue_depth: 4096,
        lanes_per_model: 2,
        admission_cap: 3,
        ..ServeConfig::default()
    });
    reg.register_file("m", &path).unwrap();

    let mut rng = Rng::new(4711);
    let (mut submitted, mut answered, mut shed) = (0u64, 0u64, 0u64);
    let mut swap_to_b = true;
    for _round in 0..12 {
        // burst far past the admission cap: the submit loop outruns the
        // service loop, so a slice of each burst must shed
        let client = reg.client("m", VARIANT_INT8).unwrap();
        let burst = 16 + rng.below(48);
        let mut pending = Vec::with_capacity(burst);
        for i in 0..burst {
            let prio = if i % 3 == 0 {
                Priority::Batch
            } else {
                Priority::Interactive
            };
            submitted += 1;
            match client.submit_prio(x.clone(), prio) {
                Ok(rx) => pending.push(rx),
                Err(e) => {
                    match e.downcast_ref::<SubmitError>() {
                        Some(SubmitError::Shed { in_flight, cap }) => {
                            assert!(
                                in_flight >= cap,
                                "shed below the admission cap"
                            );
                            shed += 1;
                        }
                        other => panic!(
                            "expected the typed Shed rejection, got \
                             {other:?}: {e:#}"
                        ),
                    };
                }
            }
        }
        // random lifecycle op with the burst still in flight: hot swap
        // (retired lanes drain concurrently), evict (shutdown drains
        // queued jobs), or nothing
        match rng.below(3) {
            0 => {
                let q = if swap_to_b { &qb } else { &qa };
                q.save_artifact(&path, PlanOpts::default()).unwrap();
                swap_to_b = !swap_to_b;
                reg.reload("m").unwrap();
            }
            1 => {
                assert!(reg.evict("m").unwrap());
            }
            _ => {}
        }
        // drain: every admitted request resolves with a real answer —
        // from the old generation or the new one, never an error
        for rx in pending {
            let y = rx
                .recv()
                .expect("request vanished (reply channel dropped)")
                .expect("admitted request answered with an error");
            assert_eq!(y.shape()[0], 1);
            answered += 1;
        }
    }
    assert_eq!(
        answered + shed,
        submitted,
        "exactly-once violated: {answered} answered + {shed} shed != \
         {submitted} submitted"
    );
    assert!(shed > 0, "over-capacity bursts never exercised the shed path");
    assert!(answered > 0, "admission starved every request");
    reg.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Per-lane metrics merge invariant: lane views sum to the shared
/// per-variant view, and the shared totals match a single-lane baseline
/// serving the identical workload (same outputs, same counts).
#[test]
fn lane_metrics_sum_to_shared_view_and_match_single_lane_baseline() {
    let q = Arc::new(quantized(73));
    let x = testutil::random_input(&q.model, 1, 5);
    let want = q.pack_int8().unwrap().run(&x).unwrap();
    let requests = 30usize;

    let run = |lanes: usize| {
        let q = Arc::clone(&q);
        let server = Server::start_sharded(
            ServeConfig {
                max_batch: 4,
                max_delay: Duration::from_millis(1),
                queue_depth: 2048,
                lanes_per_model: lanes,
                ..ServeConfig::default()
            },
            move || {
                Ok(Box::new(QuantExecutor::from_quantized(&q, 4)?)
                    as Box<dyn BatchExecutor>)
            },
        );
        assert_eq!(server.lanes(), lanes);
        let client = server.client();
        let pending: Vec<_> = (0..requests)
            .map(|i| {
                let prio = if i % 2 == 0 {
                    Priority::Interactive
                } else {
                    Priority::Batch
                };
                client.submit_prio(x.clone(), prio).unwrap()
            })
            .collect();
        for rx in pending {
            let y = rx.recv().unwrap().unwrap();
            assert_eq!(y.data(), want.data(), "lane output drifted");
        }
        let lane_sum: u64 = server
            .lane_metrics()
            .iter()
            .map(|m| m.snapshot().completed)
            .sum();
        let shared = server.shutdown();
        (lane_sum, shared)
    };

    let (lane_sum_1, baseline) = run(1);
    let (lane_sum_3, sharded) = run(3);

    // every lane view merges into the shared view, on both shapes
    assert_eq!(lane_sum_1, baseline.completed);
    assert_eq!(lane_sum_3, sharded.completed, "lane views lost traffic");

    // the sharded totals equal the single-lane baseline's
    assert_eq!(sharded.completed, baseline.completed);
    assert_eq!(sharded.completed, requests as u64);
    assert_eq!(sharded.accepted, baseline.accepted);
    assert_eq!(sharded.shed, 0);
    assert_eq!(baseline.shed, 0);
}
