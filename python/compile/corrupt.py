"""Ill-conditioning corruption — the MobileNetV2 pathology, synthesised.

The paper's headline failure mode (Fig. 2, §3.1) is strong per-output-
channel weight-range disparity that makes per-tensor INT8 quantisation
collapse. Trained-from-scratch micro models are too well-conditioned to
show it, so we *induce* it through the very invariance DFQ exploits
(eq. 5-7): at every CLE-eligible pair boundary, scale BN's affine output
of the first conv per channel by ``s_i`` and divide the second conv's
matching input-channel weights by ``s_i``.

Exactness: for ReLU / linear chains this preserves the FP32 function
bit-for-bit (up to fp rounding). For ReLU6 the clip breaks positive
homogeneity, so ``s_i`` is bounded per channel by ``6 / zmax_i`` (the
channel's observed post-BN maximum on training data); channels that
already saturate are left untouched. The corrupted model therefore keeps
the original FP32 accuracy while per-tensor INT8 collapses — precisely
the paper's starting point.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import layers, specs

SMAX = 200.0  # scale magnitude bound, log-uniform in [1/SMAX, SMAX]
# At 200x the per-tensor INT8 grid starves the downscaled channels
# (~1 level) and the corrupted "original model" collapses to chance,
# matching the paper's Table 1 starting point; CLE recovers it exactly.


def channel_zmax(nodes, outputs, params, x, bs=256):
    """Per-channel max of every bn node's output over data ``x``."""
    zmax = {}
    for i in range(0, x.shape[0], bs):
        _, vals, _ = layers.forward(
            nodes, outputs, params, jnp.asarray(x[i:i + bs]), False)
        for n in nodes:
            if n["op"] != "bn":
                continue
            m = np.asarray(jnp.max(vals[n["id"]], axis=(0, 2, 3)))
            zmax[n["id"]] = (np.maximum(zmax[n["id"]], m)
                             if n["id"] in zmax else m)
    return zmax


def _chain_between(nodes, a_id, b_id):
    """The (bn, act) nodes on the single-consumer chain a -> b."""
    by_id = {n["id"]: n for n in nodes}
    bn, act = None, None
    cur = a_id
    while cur != b_id:
        cons = specs.consumers(nodes, cur)
        assert len(cons) == 1
        nxt = cons[0]
        if nxt["op"] == "bn":
            bn = nxt
        elif nxt["op"] == "act":
            act = nxt
        cur = nxt["id"]
        if cur == b_id:
            break
    _ = by_id
    return bn, act


def corrupt(nodes, outputs, params, x_train, seed: int = 0,
            smax: float = SMAX):
    """Apply the corruption in place on a params dict copy; returns it."""
    params = dict(params)
    zmax = channel_zmax(nodes, outputs, params, x_train[:1024])
    rng = np.random.default_rng(seed + 77)
    by_id = {n["id"]: n for n in nodes}
    n_scaled = 0
    for a_id, b_id in specs.cle_pairs(nodes):
        bn, act = _chain_between(nodes, a_id, b_id)
        if bn is None:
            continue  # no BN to carry the scale (not present in the zoo)
        ch = bn["ch"]
        lo = np.full(ch, 1.0 / smax, np.float32)
        hi = np.full(ch, smax, np.float32)
        if act is not None and act["kind"] == "relu6":
            z = zmax[bn["id"]]
            sat = z > 6.0
            hi = np.minimum(hi, np.where(z > 0, 6.0 / np.maximum(z, 1e-6),
                                         smax))
            hi = np.maximum(hi, 1.0)          # keep interval non-empty
            lo[sat] = 1.0
            hi[sat] = 1.0
        s = np.exp(rng.uniform(np.log(lo), np.log(np.maximum(hi, lo))))
        s = s.astype(np.float32)
        n_scaled += int(np.sum(s != 1.0))

        params[bn["gamma"]] = np.asarray(params[bn["gamma"]]) * s
        params[bn["beta"]] = np.asarray(params[bn["beta"]]) * s

        b = by_id[b_id]
        w = np.asarray(params[b["w"]], np.float32).copy()
        if b["groups"] == b["in_ch"] and b["groups"] > 1:   # depthwise
            w /= s[:, None, None, None]
        else:
            w /= s[None, :, None, None]
        params[b["w"]] = w
    print(f"  corrupted {n_scaled} channels over "
          f"{len(specs.cle_pairs(nodes))} CLE pairs")
    return params
