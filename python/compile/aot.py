"""AOT build orchestrator: ``make artifacts`` entrypoint.

Runs exactly once (Make caches on the python sources): trains the micro
model zoo, applies the ill-conditioning corruption, writes ``.dfqm``
model containers and ``.dfqd`` datasets, and lowers the folded quant-sim
forward of every (architecture, batch) to HLO **text** — the interchange
format the Rust runtime loads (see /opt/xla-example/README.md: serialized
HloModuleProto from jax >= 0.5 is rejected by xla_extension 0.5.1; text
round-trips cleanly).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corrupt as C
from . import data as D
from . import dfqm, model, specs, train

BATCH_SIZES = (1, 64)
N_TEST = 1024
N_CALIB = 512

TRAIN_CFG = {
    "micronet_v2": dict(steps=600),
    "micronet_v1": dict(steps=600),
    "microresnet18": dict(steps=600),
    "microdeeplab": dict(steps=650),
    "microssd": dict(steps=1000),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_model(nodes, outputs, input_shape, batch: int) -> tuple[str, dict]:
    """Lower the folded quant-sim forward; returns (hlo_text, meta)."""
    folded, remap = model.fold_spec(nodes)
    order = model.weight_args(folded)
    sites = model.act_sites(folded)
    shapes = {}
    for n in nodes:
        if n["op"] == "conv":
            shapes[n["w"]] = (n["out_ch"], n["in_ch"] // n["groups"],
                              n["k"], n["k"])
            shapes[n["b"] or f"fb{n['id']}"] = (n["out_ch"],)
        elif n["op"] == "linear":
            shapes[n["w"]] = (n["out_dim"], n["in_dim"])
            shapes[n["b"]] = (n["out_dim"],)

    x_spec = jax.ShapeDtypeStruct((batch, *input_shape), jnp.float32)
    w_specs = [jax.ShapeDtypeStruct(shapes[name], jnp.float32)
               for name, _ in order]
    q_spec = jax.ShapeDtypeStruct((len(sites), 4), jnp.float32)

    def fn(x, *rest):
        weights, qcfg = rest[:-1], rest[-1]
        return model.quantsim_forward(folded, outputs, remap,
                                      list(weights), x, qcfg)

    lowered = jax.jit(fn).lower(x_spec, *w_specs, q_spec)
    meta = {
        "weight_args": [[name, kind, list(shapes[name])]
                        for name, kind in order],
        "sites": sites,
        "num_outputs": len(outputs),
        "batch": batch,
    }
    return to_hlo_text(lowered), meta


def lower_kernel_bench(m=1024, k=64, n=64) -> str:
    """Standalone fused-kernel HLO for the Rust microbench."""
    from .kernels.fq_matmul import fq_matmul
    s = jax.ShapeDtypeStruct
    lowered = jax.jit(
        lambda x, w, b, c: (fq_matmul(x, w, b, c),)
    ).lower(s((m, k), jnp.float32), s((k, n), jnp.float32),
            s((n,), jnp.float32), s((8,), jnp.float32))
    return to_hlo_text(lowered)


def build_datasets(out: str, manifest: dict):
    ds = {}
    for task, gen in (("classification", D.make_classification),
                      ("segmentation", D.make_segmentation),
                      ("detection", D.make_detection)):
        x_test, y_test = gen(N_TEST, seed=1234)
        x_cal, y_cal = gen(N_CALIB, seed=5678)
        files = {}
        for split, (x, y) in (("test", (x_test, y_test)),
                              ("calib", (x_cal, y_cal))):
            path = f"{task}_{split}.dfqd"
            arrs = {"x": x.astype(np.float32)}
            if task == "detection":
                arrs["boxes"] = y.astype(np.float32)
            else:
                arrs["y"] = y.astype(np.int32)
            dfqm.write_dataset(os.path.join(out, path),
                               f"synthshapes-{task}-{split}", task, arrs)
            files[split] = path
        ds[task] = files
    manifest["datasets"] = ds


def relower_arch(name: str, out: str, manifest: dict):
    """Re-lower HLO for an already-trained arch (tile/kernel changes;
    no retraining). Reads the graph spec back from the .dfqm header."""
    t0 = time.time()
    header, _ = dfqm.read(os.path.join(out, f"{name}.dfqm"))
    nodes, outputs = header["nodes"], header["outputs"]
    entry = manifest["archs"][name]
    for b in BATCH_SIZES:
        hlo, meta = lower_model(nodes, outputs, header["input_shape"], b)
        path = f"{name}_b{b}.hlo.txt"
        with open(os.path.join(out, path), "w") as f:
            f.write(hlo)
        entry["hlo"][str(b)] = path
        entry.update({k: v for k, v in meta.items() if k != "batch"})
    print(f"  [{name}] re-lowered in {time.time()-t0:.0f}s")


def build_arch(name: str, out: str, manifest: dict, fast: bool):
    cfg = dict(TRAIN_CFG[name])
    if fast:
        cfg["steps"] = 60
    t0 = time.time()
    params, (nodes, outputs, task, shapes, input_shape) = train.train(
        name, **cfg)
    if task == "classification":
        x_train = D.make_classification(512, seed=42)[0]
    elif task == "segmentation":
        x_train = D.make_segmentation(512, seed=42)[0]
    else:
        x_train = D.make_detection(512, seed=42)[0]
    params_np = {k: np.asarray(v) for k, v in params.items()}
    corrupted = C.corrupt(nodes, outputs, params_np, x_train)

    for tag, p in (("", corrupted), ("_clean", params_np)):
        dfqm.write_model(
            os.path.join(out, f"{name}{tag}.dfqm"),
            name, task, input_shape, D.CLS_CLASSES, nodes, outputs,
            {k: np.asarray(v, np.float32) for k, v in p.items()},
            meta={"corrupted": tag == ""})

    entry = {"task": task, "model": f"{name}.dfqm",
             "model_clean": f"{name}_clean.dfqm", "hlo": {}}
    for b in BATCH_SIZES:
        hlo, meta = lower_model(nodes, outputs, input_shape, b)
        path = f"{name}_b{b}.hlo.txt"
        with open(os.path.join(out, path), "w") as f:
            f.write(hlo)
        entry["hlo"][str(b)] = path
        entry.update({k: v for k, v in meta.items() if k != "batch"})
    manifest["archs"][name] = entry
    print(f"  [{name}] done in {time.time()-t0:.0f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--fast", action="store_true",
                    help="tiny step count — CI smoke only")
    ap.add_argument("--archs", default=",".join(specs.ARCHS))
    ap.add_argument("--lower-only", action="store_true",
                    help="re-lower HLO from existing .dfqm files")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    # partial rebuilds (--archs subset) merge into an existing manifest
    manifest = {"version": 1, "archs": {}}
    prev = os.path.join(args.out, "manifest.json")
    if os.path.exists(prev):
        with open(prev) as f:
            manifest = json.load(f)
    if args.lower_only:
        for name in args.archs.split(","):
            relower_arch(name, args.out, manifest)
        with open(os.path.join(args.out, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        print("re-lowering complete")
        return

    build_datasets(args.out, manifest)
    for name in args.archs.split(","):
        build_arch(name, args.out, manifest, args.fast)

    with open(os.path.join(args.out, "kernel_fq_matmul.hlo.txt"), "w") as f:
        f.write(lower_kernel_bench())
    manifest["kernel_bench"] = {"hlo": "kernel_fq_matmul.hlo.txt",
                                "m": 1024, "k": 64, "n": 64}

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("artifacts complete")


if __name__ == "__main__":
    main()
