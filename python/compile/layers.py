"""JAX interpreters over architecture specs (training mode).

``forward`` evaluates a spec with live BatchNorm (batch statistics during
training, running statistics at eval) and returns every intermediate
tensor, which the corruption pass uses to bound per-channel activation
maxima. The folded quant-sim interpreter lives in :mod:`compile.model`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

BN_EPS = 1e-5
BN_MOMENTUM = 0.9


def depthwise_conv2d(x, w, stride, pad):
    """Depthwise conv as k*k shifted fused multiply-adds.

    XLA CPU lowers grouped convolutions to a scalar loop that is ~20x
    slower than this formulation (measured: 196 ms vs <2 ms for a
    96x64x16x16 / 3x3 layer); the same win carries into the AOT-lowered
    quant-sim executable the Rust runtime loads.
    """
    c, _, kh, kw = w.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh = (x.shape[2] + 2 * pad - kh) // stride + 1
    ow = (x.shape[3] + 2 * pad - kw) // stride + 1
    acc = jnp.zeros((x.shape[0], c, oh, ow), jnp.float32)
    for dy in range(kh):
        for dx in range(kw):
            sl = xp[:, :, dy:dy + (oh - 1) * stride + 1:stride,
                    dx:dx + (ow - 1) * stride + 1:stride]
            acc = acc + sl * w[:, 0, dy, dx][None, :, None, None]
    return acc


def conv2d(x, w, stride, pad, groups):
    if groups > 1 and groups == x.shape[1] and w.shape[1] == 1:
        return depthwise_conv2d(x, w, stride, pad)
    return lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups,
    )


def activation(x, kind):
    if kind == "relu":
        return jnp.maximum(x, 0.0)
    if kind == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    raise ValueError(kind)


def init_params(rng, shapes, nodes):
    """He-normal conv/linear weights; BN gamma=1, beta=0, mean=0, var=1."""
    params = {}
    bn_names = set()
    for n in nodes:
        if n["op"] == "bn":
            bn_names.update(n[f] for f in ("gamma", "beta", "mean", "var"))
    gamma_like = {n["gamma"] for n in nodes if n["op"] == "bn"}
    var_like = {n["var"] for n in nodes if n["op"] == "bn"}
    keys = jax.random.split(rng, len(shapes))
    for key, (name, shape) in zip(keys, sorted(shapes.items())):
        if name in bn_names:
            if name in gamma_like or name in var_like:
                params[name] = jnp.ones(shape, jnp.float32)
            else:
                params[name] = jnp.zeros(shape, jnp.float32)
        elif len(shape) == 1:  # bias
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = 1
            for d in shape[1:]:
                fan_in *= d
            std = (2.0 / fan_in) ** 0.5
            params[name] = std * jax.random.normal(key, shape, jnp.float32)
    return params


def forward(nodes, outputs, params, x, train: bool):
    """Interpret the spec. Returns (outs, tensors, bn_updates).

    ``bn_updates`` maps running-stat tensor names to their new values
    (empty dict when ``train`` is False).
    """
    vals = {0: x}
    bn_updates = {}
    for n in nodes:
        op = n["op"]
        if op == "input":
            continue
        a = vals[n["inputs"][0]]
        if op == "conv":
            y = conv2d(a, params[n["w"]], n["stride"], n["pad"], n["groups"])
            if n["b"] is not None:
                y = y + params[n["b"]][None, :, None, None]
        elif op == "bn":
            g, b = params[n["gamma"]], params[n["beta"]]
            if train:
                mu = jnp.mean(a, axis=(0, 2, 3))
                var = jnp.var(a, axis=(0, 2, 3))
                bn_updates[n["mean"]] = (
                    BN_MOMENTUM * params[n["mean"]] + (1 - BN_MOMENTUM) * mu)
                bn_updates[n["var"]] = (
                    BN_MOMENTUM * params[n["var"]] + (1 - BN_MOMENTUM) * var)
            else:
                mu, var = params[n["mean"]], params[n["var"]]
            inv = g / jnp.sqrt(var + BN_EPS)
            y = (a - mu[None, :, None, None]) * inv[None, :, None, None] \
                + b[None, :, None, None]
        elif op == "act":
            y = activation(a, n["kind"])
        elif op == "add":
            y = a + vals[n["inputs"][1]]
        elif op == "gap":
            y = jnp.mean(a, axis=(2, 3))
        elif op == "linear":
            y = a @ params[n["w"]].T + params[n["b"]]
        elif op == "upsample":
            f = n["factor"]
            y = jnp.repeat(jnp.repeat(a, f, axis=2), f, axis=3)
        else:
            raise ValueError(op)
        vals[n["id"]] = y
    return [vals[o] for o in outputs], vals, bn_updates
