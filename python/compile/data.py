"""SynthShapes — procedural datasets for the DFQ reproduction.

The paper evaluates on ImageNet / Pascal VOC with pretrained MobileNets.
Neither the data nor the checkpoints are available here (repro band 0/5),
so we substitute seeded procedural datasets that exercise the same code
paths (see DESIGN.md §1):

* ``SynthShapes-10``  — 10-way classification, 32x32x3.
* ``SynthShapes-seg`` — 4-class per-pixel segmentation (bg + 3 shapes).
* ``SynthShapes-det`` — 1..3 shapes with boxes, 3 foreground classes.

Everything is numpy-vectorised; generation of the full corpus takes a few
seconds on one core. Containers are written by :mod:`compile.dfqm`.
"""

from __future__ import annotations

import numpy as np

IMG = 32  # image side
CLS_CLASSES = 10
SEG_CLASSES = 4  # 0 = background
DET_CLASSES = 3
DET_MAX_OBJ = 3

# Shape ids used across tasks. The first DET_CLASSES are the detection /
# segmentation foreground shapes.
SHAPES = [
    "circle", "square", "triangle", "cross", "ring",
    "diamond", "hbar", "vbar", "checker", "dots",
]


def _grid():
    ys, xs = np.mgrid[0:IMG, 0:IMG].astype(np.float32) + 0.5
    return xs, ys


def shape_mask(shape: str, cx, cy, r):
    """Boolean mask (N, IMG, IMG) for N shape instances.

    ``cx, cy, r`` are float arrays of shape (N,).
    """
    xs, ys = _grid()
    cx = np.asarray(cx, np.float32)[:, None, None]
    cy = np.asarray(cy, np.float32)[:, None, None]
    r = np.asarray(r, np.float32)[:, None, None]
    dx, dy = xs[None] - cx, ys[None] - cy
    ax, ay = np.abs(dx), np.abs(dy)
    if shape == "circle":
        return dx * dx + dy * dy <= r * r
    if shape == "square":
        return np.maximum(ax, ay) <= r
    if shape == "triangle":
        # upward triangle: inside |dx| <= (r - dy_shifted)/ slope
        return (dy >= -r) & (dy <= r) & (ax <= (dy + r) * 0.6)
    if shape == "cross":
        t = np.maximum(r * 0.35, 1.2)
        return ((ax <= t) & (ay <= r)) | ((ay <= t) & (ax <= r))
    if shape == "ring":
        d2 = dx * dx + dy * dy
        return (d2 <= r * r) & (d2 >= (0.55 * r) ** 2)
    if shape == "diamond":
        return ax + ay <= r * 1.3
    if shape == "hbar":
        return (ax <= r * 1.3) & (ay <= r * 0.45)
    if shape == "vbar":
        return (ay <= r * 1.3) & (ax <= r * 0.45)
    if shape == "checker":
        box = np.maximum(ax, ay) <= r
        par = ((xs[None] // 3).astype(np.int32) + (ys[None] // 3).astype(np.int32)) % 2 == 0
        return box & par
    if shape == "dots":
        box = np.maximum(ax, ay) <= r
        par = ((xs[None] % 5) < 2.5) & ((ys[None] % 5) < 2.5)
        return box & par
    raise ValueError(f"unknown shape {shape}")


def _render(n, masks_colors, rng):
    """Compose images from a list of (mask(N,H,W), color(N,3)) layers."""
    img = rng.uniform(0.0, 0.25, size=(n, IMG, IMG, 3)).astype(np.float32)
    # low-frequency background tint per image
    tint = rng.uniform(0.0, 0.3, size=(n, 1, 1, 3)).astype(np.float32)
    img += tint
    for mask, color in masks_colors:
        m = mask[..., None].astype(np.float32)
        img = img * (1 - m) + m * color[:, None, None, :]
    img += rng.normal(0.0, 0.04, size=img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def _rand_color(rng, n, lo=0.45):
    c = rng.uniform(lo, 1.0, size=(n, 3)).astype(np.float32)
    # knock one channel down for saturation
    ch = rng.integers(0, 3, size=n)
    c[np.arange(n), ch] *= rng.uniform(0.0, 0.5, size=n).astype(np.float32)
    return c


def make_classification(n: int, seed: int):
    """Images (N,3,32,32) f32 NCHW + labels (N,) i32."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, CLS_CLASSES, size=n).astype(np.int32)
    cx = rng.uniform(10, IMG - 10, size=n)
    cy = rng.uniform(10, IMG - 10, size=n)
    r = rng.uniform(6.0, 10.0, size=n)
    color = _rand_color(rng, n)
    masks = np.zeros((n, IMG, IMG), dtype=bool)
    for k, name in enumerate(SHAPES):
        idx = np.where(labels == k)[0]
        if idx.size:
            masks[idx] = shape_mask(name, cx[idx], cy[idx], r[idx])
    imgs = _render(n, [(masks, color)], rng)
    return imgs.transpose(0, 3, 1, 2).copy(), labels


def make_segmentation(n: int, seed: int):
    """Images (N,3,32,32) + per-pixel labels (N,32,32) i32 in [0,SEG_CLASSES)."""
    rng = np.random.default_rng(seed)
    seg = np.zeros((n, IMG, IMG), dtype=np.int32)
    layers = []
    n_obj = rng.integers(1, 3, size=n)  # 1..2 shapes
    for j in range(2):
        active = n_obj > j
        cls = rng.integers(0, DET_CLASSES, size=n).astype(np.int32)
        cx = rng.uniform(8, IMG - 8, size=n)
        cy = rng.uniform(8, IMG - 8, size=n)
        r = rng.uniform(4.5, 8.0, size=n)
        color = _rand_color(rng, n)
        masks = np.zeros((n, IMG, IMG), dtype=bool)
        for k in range(DET_CLASSES):
            idx = np.where(active & (cls == k))[0]
            if idx.size:
                masks[idx] = shape_mask(SHAPES[k], cx[idx], cy[idx], r[idx])
        layers.append((masks, color))
        for k in range(DET_CLASSES):
            sel = active & (cls == k)
            seg[sel] = np.where(masks[sel], k + 1, seg[sel])
    imgs = _render(n, layers, rng)
    return imgs.transpose(0, 3, 1, 2).copy(), seg


def make_detection(n: int, seed: int):
    """Images + boxes (N, DET_MAX_OBJ, 5) f32 rows ``[cls, x1, y1, x2, y2]``.

    ``cls`` is -1 for padding rows; coordinates are in pixels.
    """
    rng = np.random.default_rng(seed)
    boxes = np.full((n, DET_MAX_OBJ, 5), -1.0, dtype=np.float32)
    layers = []
    n_obj = rng.integers(1, DET_MAX_OBJ + 1, size=n)
    # objects occupy *distinct* 3x3 placement cells (sampled without
    # replacement per image) so boxes never overlap and each object lands
    # in its own detection-grid cell
    cells = np.stack([rng.permutation(9)[:DET_MAX_OBJ] for _ in range(n)])
    for j in range(DET_MAX_OBJ):
        active = n_obj > j
        cls = rng.integers(0, DET_CLASSES, size=n).astype(np.int32)
        gx = cells[:, j] % 3  # 3x3 placement cells
        gy = cells[:, j] // 3
        cx = gx * 10 + rng.uniform(5.0, 7.0, size=n)
        cy = gy * 10 + rng.uniform(5.0, 7.0, size=n)
        r = rng.uniform(3.5, 5.5, size=n)
        color = _rand_color(rng, n)
        masks = np.zeros((n, IMG, IMG), dtype=bool)
        for k in range(DET_CLASSES):
            idx = np.where(active & (cls == k))[0]
            if idx.size:
                masks[idx] = shape_mask(SHAPES[k], cx[idx], cy[idx], r[idx])
        layers.append((masks, color))
        sel = np.where(active)[0]
        boxes[sel, j, 0] = cls[sel]
        boxes[sel, j, 1] = np.clip(cx[sel] - r[sel], 0, IMG)
        boxes[sel, j, 2] = np.clip(cy[sel] - r[sel], 0, IMG)
        boxes[sel, j, 3] = np.clip(cx[sel] + r[sel], 0, IMG)
        boxes[sel, j, 4] = np.clip(cy[sel] + r[sel], 0, IMG)
    imgs = _render(n, layers, rng)
    return imgs.transpose(0, 3, 1, 2).copy(), boxes
