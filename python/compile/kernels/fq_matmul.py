"""Layer-1 Pallas kernel: fused fake-quantized matmul.

The inference hot-spot of the quant-sim models: every pointwise (1x1)
convolution and the classifier head lower to

    y = fq( clip( x @ w + b, lo, hi ), scale, zp, n )

with the epilogue (bias add, clipped-linear activation, activation
fake-quantisation) fused into the matmul so each output tile makes a
single HBM round-trip — the TPU analogue of the paper's fused integer
pipeline (DESIGN.md §2).

TPU mapping
-----------
* grid = (M/tm, N/tn); the full K dimension stays VMEM-resident per tile
  (channels are <= 160 in the micro zoo, so tm*K + K*tn + tm*tn floats is
  ~230 KB at the largest tiling — well under VMEM).
* tiles target the MXU: tm in {16,..,256} and tn multiples of 8.
* ``interpret=True`` everywhere: CPU PJRT cannot execute Mosaic
  custom-calls; the kernel still lowers into the same HLO as the model.

The epilogue config rides in an 8-float operand broadcast to every tile:
``[clip_lo, clip_hi, scale, zero_point, n_levels, 0, 0, 0]``.
``n_levels == 0`` disables fake-quant (pure matmul+bias+clip).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile-size ladder; pick the largest that divides the dimension.
#
# §Perf note: tm tops out at 2048 — the largest M-tile whose VMEM
# footprint (tm*K + K*tn + tm*tn floats, K <= 160 in the zoo) stays
# under ~2.5 MB, leaving ample double-buffering headroom in a 16 MB
# VMEM. Larger M-tiles also cut the sequential grid-step count of the
# interpret-mode lowering 8x, which dominated batch-64 latency on the
# CPU PJRT backend (EXPERIMENTS.md §Perf).
_TM_CHOICES = (2048, 1024, 512, 256, 128, 64, 32, 16, 8)
_TN_CHOICES = (128, 64, 32, 16, 8)


def pick_tile(dim: int, choices) -> int:
    for t in choices:
        if dim % t == 0:
            return t
    return 0


def supported(m: int, n: int) -> bool:
    """Whether the pallas path can tile this problem."""
    return pick_tile(m, _TM_CHOICES) > 0 and pick_tile(n, _TN_CHOICES) > 0


def _kernel(x_ref, w_ref, b_ref, cfg_ref, o_ref):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    cfg = cfg_ref[...]
    lo, hi, scale, zp, n = cfg[0], cfg[1], cfg[2], cfg[3], cfg[4]
    acc = jnp.clip(acc, lo, hi)
    # fake-quant: quantize-dequantize on the fp32 grid (paper's simulation).
    s = jnp.where(n > 0, scale, 1.0)
    q = jnp.round(acc / s) + zp
    q = jnp.clip(q, 0.0, jnp.maximum(n - 1.0, 1.0))
    fq = (q - zp) * s
    o_ref[...] = jnp.where(n > 0, fq, acc)


@functools.partial(jax.named_call, name="fq_matmul")
def fq_matmul(x, w, b, cfg):
    """``fq(clip(x @ w + b))`` — x:(M,K) w:(K,N) b:(N,) cfg:(8,)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,) and cfg.shape == (8,)
    tm, tn = pick_tile(m, _TM_CHOICES), pick_tile(n, _TN_CHOICES)
    assert tm and tn, f"untileable problem ({m}, {n}); use the jnp fallback"
    return pl.pallas_call(
        _kernel,
        grid=(m // tm, n // tn),
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tn), lambda i, j: (0, j)),
            pl.BlockSpec((tn,), lambda i, j: (j,)),
            pl.BlockSpec((8,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b, cfg)


def vmem_bytes(m: int, n: int, k: int) -> int:
    """Estimated VMEM footprint of one grid step (f32)."""
    tm, tn = pick_tile(m, _TM_CHOICES), pick_tile(n, _TN_CHOICES)
    return 4 * (tm * k + k * tn + tn + 8 + tm * tn)


def mxu_utilization(m: int, n: int, k: int) -> float:
    """Fraction of 128x128x128 MXU macro-ops doing useful work."""
    tm, tn = pick_tile(m, _TM_CHOICES), pick_tile(n, _TN_CHOICES)
    pad = lambda d, t: -(-d // t) * t
    useful = m * n * k
    issued = pad(tm, 128) * pad(tn, 128) * pad(k, 128) * (m // tm) * (n // tn)
    return useful / issued
