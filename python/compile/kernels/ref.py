"""Pure-jnp oracle for the L1 kernel — the correctness reference.

Every behaviour of :func:`compile.kernels.fq_matmul.fq_matmul` must match
this function bit-for-bit under ``assert_allclose`` (pytest +
hypothesis sweep in python/tests/test_kernel.py). The Rust quant-sim
engine (rust/src/nn) implements the same semantics; keeping this oracle
tiny and obviously-correct anchors all three implementations.
"""

from __future__ import annotations

import jax.numpy as jnp


def fake_quant(x, scale, zero_point, n_levels):
    """Quantize-dequantize on the fp32 grid; identity when n_levels == 0.

    Rounding is ties-to-even (jnp.round), matching f32::round_ties_even
    on the Rust side.
    """
    s = jnp.where(n_levels > 0, scale, 1.0)
    q = jnp.round(x / s) + zero_point
    q = jnp.clip(q, 0.0, jnp.maximum(n_levels - 1.0, 1.0))
    return jnp.where(n_levels > 0, (q - zero_point) * s, x)


def fq_matmul_ref(x, w, b, cfg):
    """Reference for the fused kernel: fq(clip(x @ w + b, lo, hi))."""
    lo, hi, scale, zp, n = cfg[0], cfg[1], cfg[2], cfg[3], cfg[4]
    y = x @ w + b[None, :]
    y = jnp.clip(y, lo, hi)
    return fake_quant(y, scale, zp, n)
