"""Build-time training of the micro model zoo (single-core CPU budget).

Runs once under ``make artifacts``. Hand-rolled Adam (no optax in the
image); losses per task:

* classification — softmax cross-entropy,
* segmentation   — per-pixel cross-entropy,
* detection      — per-cell cross-entropy (background = class 0) +
                   L1 box regression on positive cells (SSD-lite style).
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import layers, specs

GRID = 4          # detection grid (stride 8 over 32px)
CELL = D.IMG // GRID


def adam_init(params):
    z = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in grads}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in grads}
    mhat = {k: m[k] / (1 - b1 ** t) for k in m}
    vhat = {k: v[k] / (1 - b2 ** t) for k in v}
    new = {k: params[k] - lr * mhat[k] / (jnp.sqrt(vhat[k]) + eps)
           for k in params}
    return new, {"m": m, "v": v, "t": t}


def xent(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[..., None], axis=-1))


def det_targets(boxes: np.ndarray):
    """Precompute SSD-lite targets from (N, MAX_OBJ, 5) box rows.

    Returns (cls_grid (N,G,G) i32, box_grid (N,G,G,4) f32, pos (N,G,G) f32).
    Class 0 is background; boxes are (cx, cy, w, h) in cell units.
    """
    n = boxes.shape[0]
    cls = np.zeros((n, GRID, GRID), np.int32)
    reg = np.zeros((n, GRID, GRID, 4), np.float32)
    pos = np.zeros((n, GRID, GRID), np.float32)
    for j in range(boxes.shape[1]):
        b = boxes[:, j]
        valid = b[:, 0] >= 0
        cx = (b[:, 1] + b[:, 3]) / 2
        cy = (b[:, 2] + b[:, 4]) / 2
        gx = np.clip((cx // CELL).astype(np.int32), 0, GRID - 1)
        gy = np.clip((cy // CELL).astype(np.int32), 0, GRID - 1)
        idx = np.where(valid)[0]
        cls[idx, gy[idx], gx[idx]] = b[idx, 0].astype(np.int32) + 1
        reg[idx, gy[idx], gx[idx], 0] = cx[idx] / CELL - gx[idx]
        reg[idx, gy[idx], gx[idx], 1] = cy[idx] / CELL - gy[idx]
        reg[idx, gy[idx], gx[idx], 2] = (b[idx, 3] - b[idx, 1]) / CELL
        reg[idx, gy[idx], gx[idx], 3] = (b[idx, 4] - b[idx, 2]) / CELL
        pos[idx, gy[idx], gx[idx]] = 1.0
    return cls, reg, pos


def make_loss(nodes, outputs, task):
    def loss_fn(params, batch, train=True):
        x = batch["x"]
        outs, _, bn_upd = layers.forward(nodes, outputs, params, x, train)
        y = outs[0]
        if task == "classification":
            loss = xent(y, batch["y"])
        elif task == "segmentation":
            logits = y.transpose(0, 2, 3, 1).reshape(-1, D.SEG_CLASSES)
            loss = xent(logits, batch["y"].reshape(-1))
        elif task == "detection":
            nc = D.DET_CLASSES + 1
            y = y.transpose(0, 2, 3, 1)           # (N, G, G, nc+4)
            cls_logits = y[..., :nc].reshape(-1, nc)
            labels = batch["cls"].reshape(-1)
            logp = jax.nn.log_softmax(cls_logits, axis=-1)
            nll = -jnp.take_along_axis(logp, labels[..., None],
                                       axis=-1)[..., 0]
            # background dominates 13/16 cells; upweight object cells
            wts = 1.0 + 4.0 * batch["pos"].reshape(-1)
            loss = jnp.sum(nll * wts) / jnp.sum(wts)
            l1 = jnp.abs(y[..., nc:] - batch["reg"])
            denom = jnp.maximum(jnp.sum(batch["pos"]), 1.0)
            loss = loss + 5.0 * jnp.sum(
                l1 * batch["pos"][..., None]) / denom
        else:
            raise ValueError(task)
        return loss, bn_upd
    return loss_fn


def accuracy(nodes, outputs, task, params, x, y, bs=256):
    """Quick FP32 monitoring metric on the training side."""
    hits, total = 0.0, 0
    for i in range(0, x.shape[0], bs):
        outs, _, _ = layers.forward(
            nodes, outputs, params, jnp.asarray(x[i:i + bs]), False)
        o = np.asarray(outs[0])
        yy = y[i:i + bs]
        if task == "classification":
            hits += (o.argmax(-1) == yy).sum()
            total += yy.shape[0]
        elif task == "segmentation":
            hits += (o.argmax(1) == yy).sum()
            total += yy.size
        elif task == "detection":
            nc = D.DET_CLASSES + 1
            pred = o[:, :nc].transpose(0, 2, 3, 1).argmax(-1)
            hits += (pred == yy).sum()
            total += yy.size
    return hits / total


def train(arch: str, seed: int = 0, steps: int = 600, bs: int = 96,
          lr: float = 3e-3, log_every: int = 200, n_train: int = 6144):
    """Train one architecture; returns (params, spec tuple, datasets)."""
    nodes, outputs, task, shapes, input_shape = specs.build(arch)
    rng = jax.random.PRNGKey(seed)
    params = layers.init_params(rng, shapes, nodes)

    if task == "classification":
        x, y = D.make_classification(n_train, seed=seed + 1)
        batches = {"x": x, "y": y}
    elif task == "segmentation":
        x, y = D.make_segmentation(n_train, seed=seed + 1)
        batches = {"x": x, "y": y}
    else:
        x, b = D.make_detection(n_train, seed=seed + 1)
        cls, reg, pos = det_targets(b)
        batches = {"x": x, "cls": cls, "reg": reg, "pos": pos}

    loss_fn = make_loss(nodes, outputs, task)

    @jax.jit
    def step(params, opt, batch, lr_t):
        (loss, bn_upd), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        # BN running stats are not differentiated; apply their EMA update.
        new_params, opt = adam_update(params, grads, opt, lr_t)
        for k, v in bn_upd.items():
            new_params[k] = v
        return new_params, opt, loss

    opt = adam_init(params)
    n = x.shape[0]
    order = np.random.default_rng(seed + 2).permutation(n)
    t0 = time.time()
    for s in range(steps):
        lo = (s * bs) % (n - bs + 1)
        idx = order[lo:lo + bs]
        batch = {k: jnp.asarray(v[idx]) for k, v in batches.items()}
        # cosine decay to 5% of the base rate
        lr_t = lr * (0.05 + 0.95 * 0.5 * (1 + np.cos(np.pi * s / steps)))
        params, opt, loss = step(params, opt, batch, jnp.float32(lr_t))
        if (s + 1) % log_every == 0 or s == 0:
            print(f"  [{arch}] step {s+1}/{steps} "
                  f"loss={float(loss):.4f} ({time.time()-t0:.0f}s)")
    key = "y" if task != "detection" else "cls"
    acc = accuracy(nodes, outputs, task, params,
                   batches["x"][:1024], batches[key][:1024])
    print(f"  [{arch}] train metric={acc:.4f}")
    return params, (nodes, outputs, task, shapes, input_shape)
