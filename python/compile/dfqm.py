"""``.dfqm`` / ``.dfqd`` containers — the python↔rust interchange format.

Layout (little-endian):

    magic   4 bytes  b"DFQM" (model) or b"DFQD" (dataset)
    version u32      currently 1
    hdr_len u64      length of the JSON header in bytes
    header  hdr_len  UTF-8 JSON
    blobs   ...      raw arrays, each 64-byte aligned, at header-recorded
                     offsets *relative to the start of the blob section*

Model header schema (see rust/src/graph/io.rs for the reader):

    {"kind": "model", "name": ..., "task": ...,
     "input_shape": [C,H,W], "num_classes": K,
     "nodes": [...],                 # graph spec, SSA node list
     "outputs": [node_id, ...],
     "tensors": {name: {"shape": [...], "dtype": "f32", "offset": o}}}

Dataset header schema:

    {"kind": "dataset", "name": ..., "task": ...,
     "arrays": {name: {"shape": [...], "dtype": "f32"|"i32", "offset": o}}}
"""

from __future__ import annotations

import json
import struct

import numpy as np

ALIGN = 64
_DTYPES = {"f32": np.float32, "i32": np.int32}


def _pad(n: int) -> int:
    return (ALIGN - n % ALIGN) % ALIGN


def write(path: str, magic: bytes, header: dict, arrays: dict):
    """Write a container. ``header[...]['offset']`` fields are filled here."""
    assert magic in (b"DFQM", b"DFQD")
    table_key = "tensors" if magic == b"DFQM" else "arrays"
    table = header[table_key] = {}
    blobs = []
    off = 0
    for name, arr in arrays.items():
        arr = np.ascontiguousarray(arr)
        if arr.dtype == np.float32:
            dt = "f32"
        elif arr.dtype == np.int32:
            dt = "i32"
        else:
            raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
        table[name] = {"shape": list(arr.shape), "dtype": dt, "offset": off}
        raw = arr.tobytes()
        blobs.append(raw)
        off += len(raw) + _pad(len(raw))
    hdr = json.dumps(header).encode("utf-8")
    with open(path, "wb") as f:
        f.write(magic)
        f.write(struct.pack("<I", 1))
        f.write(struct.pack("<Q", len(hdr)))
        f.write(hdr)
        f.write(b"\0" * _pad(16 + len(hdr)))
        for raw in blobs:
            f.write(raw)
            f.write(b"\0" * _pad(len(raw)))


def read(path: str):
    """Read a container back. Returns (header, {name: np.ndarray})."""
    with open(path, "rb") as f:
        buf = f.read()
    magic, version = buf[:4], struct.unpack("<I", buf[4:8])[0]
    assert magic in (b"DFQM", b"DFQD"), f"bad magic {magic!r}"
    assert version == 1
    (hdr_len,) = struct.unpack("<Q", buf[8:16])
    header = json.loads(buf[16 : 16 + hdr_len].decode("utf-8"))
    base = 16 + hdr_len
    base += _pad(base)
    table = header["tensors" if magic == b"DFQM" else "arrays"]
    arrays = {}
    for name, meta in table.items():
        dt = _DTYPES[meta["dtype"]]
        count = int(np.prod(meta["shape"])) if meta["shape"] else 1
        start = base + meta["offset"]
        arrays[name] = np.frombuffer(
            buf, dtype=dt, count=count, offset=start
        ).reshape(meta["shape"]).copy()
    return header, arrays


def write_model(path: str, name: str, task: str, input_shape, num_classes,
                nodes, outputs, params: dict, meta: dict | None = None):
    header = {
        "kind": "model", "name": name, "task": task,
        "input_shape": list(input_shape), "num_classes": int(num_classes),
        "nodes": nodes, "outputs": list(outputs),
    }
    if meta:
        header["meta"] = meta
    write(path, b"DFQM", header, params)


def write_dataset(path: str, name: str, task: str, arrays: dict):
    write(path, b"DFQD", {"kind": "dataset", "name": name, "task": task}, arrays)
