"""Regenerate the corrupted model containers from the saved clean
checkpoints (no retraining). Usage:

    python -m compile.recorrupt --out ../artifacts [--smax 48]
"""

from __future__ import annotations

import argparse
import os

import numpy as np

from . import corrupt as C
from . import data as D
from . import dfqm, specs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--smax", type=float, default=C.SMAX)
    ap.add_argument("--archs", default=",".join(specs.ARCHS))
    args = ap.parse_args()

    for arch in args.archs.split(","):
        clean_path = os.path.join(args.out, f"{arch}_clean.dfqm")
        header, params = dfqm.read(clean_path)
        nodes, outputs = header["nodes"], header["outputs"]
        task = header["task"]
        if task == "classification":
            x = D.make_classification(512, seed=42)[0]
        elif task == "segmentation":
            x = D.make_segmentation(512, seed=42)[0]
        else:
            x = D.make_detection(512, seed=42)[0]
        params = {k: np.asarray(v) for k, v in params.items()}
        print(f"[{arch}] corrupting with smax={args.smax}")
        cor = C.corrupt(nodes, outputs, params, x, seed=0, smax=args.smax)
        dfqm.write_model(
            os.path.join(args.out, f"{arch}.dfqm"),
            arch, task, header["input_shape"], header["num_classes"],
            nodes, outputs,
            {k: np.asarray(v, np.float32) for k, v in cor.items()},
            meta={"corrupted": True, "smax": args.smax})
    print("done")


if __name__ == "__main__":
    main()
