"""Layer-2: folded quant-sim forward — the graph that ships to Rust.

This interpreter evaluates the *BatchNorm-folded* spec with quantisation
hooks, and is what :mod:`compile.aot` lowers to HLO text. The executable
contract (DESIGN.md §3) is::

    args   = [x]  +  [w, b  per conv/linear in node order]  +  [qcfg]
    qcfg   = f32[S, 4] rows (scale, zero_point, n_levels, clip_hi)
    sites  = [input] + [act/add nodes in folded node order]

Weights arrive *already fake-quantised* (or plain FP32) from the Rust
coordinator; activation fake-quant is driven entirely by ``qcfg`` so a
single executable serves FP32 eval (n_levels = 0) and every quantised
table row. Pointwise convs and the classifier run through the fused
Pallas kernel (fq_matmul) with the following activation's clip+fq folded
into the matmul epilogue.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import fq_matmul as K
from .kernels.ref import fake_quant
from .layers import conv2d

NO_CLIP = 1e30


def fold_spec(nodes):
    """Remove bn nodes; every conv gains a bias tensor (synthetic name
    ``fb{id}`` when it had none). Returns (folded_nodes, remap) where
    remap maps original node ids to folded producer ids."""
    remap = {}
    folded = []
    for n in nodes:
        if n["op"] == "bn":
            remap[n["id"]] = remap.get(n["inputs"][0], n["inputs"][0])
            continue
        m = dict(n)
        m["inputs"] = [remap.get(i, i) for i in n["inputs"]]
        if m["op"] == "conv" and m["b"] is None:
            m["b"] = f"fb{m['id']}"
        folded.append(m)
        remap[n["id"]] = n["id"]
    return folded, remap


def weight_args(folded):
    """(name, kind) list defining the executable's weight-argument order."""
    order = []
    for n in folded:
        if n["op"] in ("conv", "linear"):
            order.append((n["w"], "weight"))
            order.append((n["b"], "bias"))
    return order


def act_sites(folded):
    """Site list: index 0 is the model input, then act/add nodes in order."""
    sites = [{"node": "input"}]
    for n in folded:
        if n["op"] in ("act", "add"):
            sites.append({"node": n["id"], "op": n["op"],
                          "kind": n.get("kind")})
    return sites


def _site_index(folded):
    idx = {"input": 0}
    i = 1
    for n in folded:
        if n["op"] in ("act", "add"):
            idx[n["id"]] = i
            i += 1
    return idx


def _fusable(folded, conv):
    """If ``conv``'s single consumer is an act node, return it — its
    clip+fq epilogue then fuses into the Pallas kernel call."""
    cons = [m for m in folded if conv["id"] in m["inputs"]]
    if len(cons) == 1 and cons[0]["op"] == "act":
        return cons[0]
    return None


def quantsim_forward(folded, outputs, remap, weights, x, qcfg):
    """Evaluate the folded graph. ``weights`` follows weight_args order."""
    wmap = {}
    order = weight_args(folded)
    assert len(weights) == len(order), (len(weights), len(order))
    for (name, _), w in zip(order, weights):
        wmap[name] = w
    site = _site_index(folded)

    def fq_site(v, s):
        row = qcfg[s]
        return fake_quant(v, row[0], row[1], row[2])

    vals = {0: fq_site(x, 0)}
    fused = {}  # act node id -> epilogue already applied by producer kernel
    for n in folded:
        op = n["op"]
        if op == "input":
            continue
        nid = n["id"]
        a = vals[n["inputs"][0]]
        if op == "conv":
            w, b = wmap[n["w"]], wmap[n["b"]]
            act = _fusable(folded, n)
            pallas_ok = (
                n["k"] == 1 and n["groups"] == 1 and n["stride"] == 1
                and K.supported(a.shape[0] * a.shape[2] * a.shape[3],
                                n["out_ch"])
            )
            if pallas_ok:
                bsz, cin, h, wd = a.shape
                x2d = a.transpose(0, 2, 3, 1).reshape(bsz * h * wd, cin)
                if act is not None:
                    row = qcfg[site[act["id"]]]
                    cfg = jnp.concatenate([
                        jnp.zeros((1,), jnp.float32), row[3:4], row[0:1],
                        row[1:2], row[2:3], jnp.zeros((3,), jnp.float32)])
                    fused[act["id"]] = True
                else:
                    cfg = jnp.array(
                        [-NO_CLIP, NO_CLIP, 1.0, 0.0, 0.0, 0, 0, 0],
                        jnp.float32)
                y2d = K.fq_matmul(x2d, w.reshape(n["out_ch"], cin).T, b, cfg)
                y = y2d.reshape(bsz, h, wd, n["out_ch"]).transpose(0, 3, 1, 2)
            else:
                y = conv2d(a, w, n["stride"], n["pad"], n["groups"])
                y = y + b[None, :, None, None]
        elif op == "act":
            if fused.get(nid):
                y = a  # epilogue already applied in the kernel
            else:
                row = qcfg[site[nid]]
                y = jnp.clip(a, 0.0, row[3])
                y = fake_quant(y, row[0], row[1], row[2])
        elif op == "add":
            y = a + vals[n["inputs"][1]]
            y = fq_site(y, site[nid])
        elif op == "gap":
            y = jnp.mean(a, axis=(2, 3))
        elif op == "linear":
            w, b = wmap[n["w"]], wmap[n["b"]]
            if K.supported(a.shape[0], n["out_dim"]):
                cfg = jnp.array([-NO_CLIP, NO_CLIP, 1.0, 0.0, 0.0, 0, 0, 0],
                                jnp.float32)
                y = K.fq_matmul(a, w.T, b, cfg)
            else:
                y = a @ w.T + b
        elif op == "upsample":
            f = n["factor"]
            y = jnp.repeat(jnp.repeat(a, f, axis=2), f, axis=3)
        else:
            raise ValueError(op)
        vals[nid] = y
    return tuple(vals[remap.get(o, o)] for o in outputs)


def fold_params(nodes, params, bn_eps=1e-5):
    """Numerically fold BN into the preceding conv (python reference;
    the production fold lives in rust/src/dfq/bn_fold.rs).

    Returns the weights list in weight_args order plus per-conv
    (|gamma|, beta) activation statistics of the folded graph, used by
    cross-checks in python/tests.
    """
    import numpy as np

    folded, _ = fold_spec(nodes)
    bn_after = {}
    for n in nodes:
        if n["op"] == "bn":
            bn_after[n["inputs"][0]] = n
    out = {}
    stats = {}
    for n in nodes:
        if n["op"] == "conv":
            w = np.asarray(params[n["w"]], np.float32).copy()
            b = (np.asarray(params[n["b"]], np.float32).copy()
                 if n["b"] else np.zeros(n["out_ch"], np.float32))
            bn = bn_after.get(n["id"])
            if bn is not None:
                g = np.asarray(params[bn["gamma"]])
                be = np.asarray(params[bn["beta"]])
                mu = np.asarray(params[bn["mean"]])
                var = np.asarray(params[bn["var"]])
                scale = g / np.sqrt(var + bn_eps)
                w *= scale[:, None, None, None]
                b = (b - mu) * scale + be
                stats[n["id"]] = (np.abs(g).astype(np.float32),
                                  be.astype(np.float32))
            name_b = n["b"] if n["b"] else f"fb{n['id']}"
            out[n["w"]] = w
            out[name_b] = b
        elif n["op"] == "linear":
            out[n["w"]] = np.asarray(params[n["w"]], np.float32)
            out[n["b"]] = np.asarray(params[n["b"]], np.float32)
    weights = [out[name] for name, _ in weight_args(folded)]
    return weights, stats
