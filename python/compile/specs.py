"""Architecture specs — the single source of truth for model graphs.

A spec is a JSON-serialisable SSA node list interpreted by three consumers:

* ``compile.layers``      — training-mode JAX forward (BatchNorm live),
* ``compile.model``       — folded quant-sim JAX forward (AOT → HLO),
* ``rust/src/graph``      — the Rust IR (DFQ passes + reference engine).

Node schema (all shapes NCHW):

    {"id", "op", "inputs": [ids...], ...op fields}

    conv:   w, b(optional), in_ch, out_ch, k, stride, pad, groups
    bn:     ch, gamma, beta, mean, var         (inference: running stats)
    act:    kind: "relu" | "relu6"
    add:    two inputs
    gap:    global average pool -> (N, C)
    linear: w, b, in_dim, out_dim
    upsample: factor (nearest-neighbour)

Micro architectures mirror the paper's model zoo at 32x32 scale
(DESIGN.md §1): MicroNet-V2 (inverted residuals + ReLU6), MicroNet-V1
(depthwise-separable chain + ReLU6), MicroResNet-18 (basic blocks + ReLU),
plus DeepLab-lite and SSD-lite heads over the V2 backbone.
"""

from __future__ import annotations

from . import data as D


class Builder:
    """Incrementally builds a node list; returns node ids."""

    def __init__(self, input_shape):
        self.nodes = [{"id": 0, "op": "input", "inputs": []}]
        self.shapes = {}  # tensor name -> shape
        self.input_shape = list(input_shape)
        self._n = 0

    def _new(self, op, inputs, **kw):
        nid = len(self.nodes)
        node = {"id": nid, "op": op, "inputs": list(inputs)}
        node.update(kw)
        self.nodes.append(node)
        return nid

    def _name(self, prefix):
        self._n += 1
        return f"{prefix}{self._n}"

    def conv(self, x, in_ch, out_ch, k, stride=1, pad=None, groups=1, bias=False):
        pad = (k // 2) if pad is None else pad
        w = self._name("w")
        self.shapes[w] = [out_ch, in_ch // groups, k, k]
        b = None
        if bias:
            b = self._name("b")
            self.shapes[b] = [out_ch]
        return self._new("conv", [x], w=w, b=b, in_ch=in_ch, out_ch=out_ch,
                         k=k, stride=stride, pad=pad, groups=groups)

    def bn(self, x, ch):
        names = {}
        for f in ("gamma", "beta", "mean", "var"):
            n = self._name(f[0] if f != "mean" else "m")
            self.shapes[n] = [ch]
            names[f] = n
        return self._new("bn", [x], ch=ch, **names)

    def act(self, x, kind):
        return self._new("act", [x], kind=kind)

    def add(self, a, b):
        return self._new("add", [a, b])

    def gap(self, x):
        return self._new("gap", [x])

    def linear(self, x, in_dim, out_dim):
        w, b = self._name("fw"), self._name("fb")
        self.shapes[w] = [out_dim, in_dim]
        self.shapes[b] = [out_dim]
        return self._new("linear", [x], w=w, b=b, in_dim=in_dim, out_dim=out_dim)

    def upsample(self, x, factor):
        return self._new("upsample", [x], factor=factor)

    # ---- composite blocks -------------------------------------------------

    def conv_bn_act(self, x, in_ch, out_ch, k, stride=1, groups=1, act="relu6"):
        c = self.conv(x, in_ch, out_ch, k, stride=stride, groups=groups)
        b = self.bn(c, out_ch)
        return self.act(b, act) if act else b

    def inverted_residual(self, x, in_ch, out_ch, stride, expand, act="relu6"):
        """MobileNetV2 block: pw-expand -> dw -> pw-project (linear)."""
        mid = in_ch * expand
        h = self.conv_bn_act(x, in_ch, mid, 1, act=act)           # expand
        h = self.conv_bn_act(h, mid, mid, 3, stride=stride, groups=mid, act=act)  # dw
        c = self.conv(h, mid, out_ch, 1)                          # project
        h = self.bn(c, out_ch)                                    # linear bottleneck
        if stride == 1 and in_ch == out_ch:
            h = self.add(h, x)
        return h

    def basic_block(self, x, in_ch, out_ch, stride):
        """ResNet-18 basic block with ReLU."""
        h = self.conv_bn_act(x, in_ch, out_ch, 3, stride=stride, act="relu")
        c = self.conv(h, out_ch, out_ch, 3)
        h = self.bn(c, out_ch)
        if stride != 1 or in_ch != out_ch:
            s = self.conv(x, in_ch, out_ch, 1, stride=stride, pad=0)
            x = self.bn(s, out_ch)
        h = self.add(h, x)
        return self.act(h, "relu")


def micronet_v2(width=1):
    """MicroNet-V2: stem + 5 inverted residual blocks + head. ReLU6."""
    b = Builder([3, D.IMG, D.IMG])
    c = [int(w * width) for w in (16, 16, 24, 24, 40, 40)]
    x = b.conv_bn_act(0, 3, c[0], 3, stride=2)                 # 16x16
    x = b.inverted_residual(x, c[0], c[1], 1, 4)
    x = b.inverted_residual(x, c[1], c[2], 2, 4)               # 8x8
    x = b.inverted_residual(x, c[2], c[3], 1, 4)
    x = b.inverted_residual(x, c[3], c[4], 2, 4)               # 4x4
    x = b.inverted_residual(x, c[4], c[5], 1, 4)
    x = b.conv_bn_act(x, c[5], 128, 1)                         # head pw
    x = b.gap(x)
    out = b.linear(x, 128, D.CLS_CLASSES)
    return b, [out], "classification"


def micronet_v1():
    """MicroNet-V1: plain depthwise-separable chain, no residuals. ReLU6."""
    b = Builder([3, D.IMG, D.IMG])

    def dw_sep(x, in_ch, out_ch, stride):
        x = b.conv_bn_act(x, in_ch, in_ch, 3, stride=stride, groups=in_ch)
        return b.conv_bn_act(x, in_ch, out_ch, 1)

    x = b.conv_bn_act(0, 3, 16, 3, stride=2)                   # 16x16
    x = dw_sep(x, 16, 32, 1)
    x = dw_sep(x, 32, 32, 1)
    x = dw_sep(x, 32, 64, 2)                                   # 8x8
    x = dw_sep(x, 64, 64, 1)
    x = dw_sep(x, 64, 128, 2)                                  # 4x4
    x = b.gap(x)
    out = b.linear(x, 128, D.CLS_CLASSES)
    return b, [out], "classification"


def microresnet18():
    """MicroResNet-18 (CIFAR layout): 3 stages of 2 basic blocks. ReLU."""
    b = Builder([3, D.IMG, D.IMG])
    x = b.conv_bn_act(0, 3, 16, 3, act="relu")                 # 32x32
    x = b.basic_block(x, 16, 16, 1)
    x = b.basic_block(x, 16, 16, 1)
    x = b.basic_block(x, 16, 32, 2)                            # 16x16
    x = b.basic_block(x, 32, 32, 1)
    x = b.basic_block(x, 32, 64, 2)                            # 8x8
    x = b.basic_block(x, 64, 64, 1)
    x = b.gap(x)
    out = b.linear(x, 64, D.CLS_CLASSES)
    return b, [out], "classification"


def _v2_backbone(b):
    """Shared MicroNet-V2 backbone ending at 8x8 (stride 4) features."""
    x = b.conv_bn_act(0, 3, 16, 3, stride=2)                   # 16x16
    x = b.inverted_residual(x, 16, 16, 1, 4)
    x = b.inverted_residual(x, 16, 24, 2, 4)                   # 8x8
    x = b.inverted_residual(x, 24, 24, 1, 4)
    x = b.inverted_residual(x, 24, 40, 1, 4)                   # stays 8x8
    x = b.inverted_residual(x, 40, 40, 1, 4)
    return x, 40


def microdeeplab():
    """DeepLab-lite: V2 backbone + dilated-free ASPP-lite head + upsample.

    Output: per-pixel logits (N, SEG_CLASSES, 32, 32).
    """
    b = Builder([3, D.IMG, D.IMG])
    x, ch = _v2_backbone(b)
    x = b.conv_bn_act(x, ch, 64, 3)                            # context 3x3
    x = b.conv_bn_act(x, 64, 64, 1)                            # pw mix
    x = b.conv(x, 64, D.SEG_CLASSES, 1, bias=True)             # classifier
    out = b.upsample(x, 4)                                     # 8x8 -> 32x32
    return b, [out], "segmentation"


def microssd():
    """SSD-lite: V2 backbone + stride-8 grid head.

    One output tensor (N, DET_CLASSES+1+4, 4, 4): per-cell class logits
    (incl. background at index 0) and box regression (cx, cy, w, h) in
    cell-relative units.
    """
    b = Builder([3, D.IMG, D.IMG])
    x, ch = _v2_backbone(b)
    x = b.inverted_residual(x, ch, 64, 2, 4)                   # 4x4
    x = b.conv_bn_act(x, 64, 64, 1)
    out = b.conv(x, 64, D.DET_CLASSES + 1 + 4, 1, bias=True)
    return b, [out], "detection"


ARCHS = {
    "micronet_v2": micronet_v2,
    "micronet_v1": micronet_v1,
    "microresnet18": microresnet18,
    "microdeeplab": microdeeplab,
    "microssd": microssd,
}


def build(name: str):
    """Return (nodes, outputs, task, param_shapes, input_shape)."""
    b, outs, task = ARCHS[name]()
    return b.nodes, outs, task, b.shapes, b.input_shape


# ---------------------------------------------------------------------------
# Structural queries shared with the Rust side (rust/src/dfq/equalize.rs
# implements the same discovery; python needs it for the ill-conditioning
# corruption in compile/corrupt.py).
# ---------------------------------------------------------------------------

def consumers(nodes, nid):
    return [n for n in nodes if nid in n["inputs"]]


def cle_pairs(nodes):
    """Find CLE-eligible (conv_a, conv_b) node-id pairs.

    A pair is eligible when conv_a's output reaches conv_b through a
    single-consumer chain of bn/act nodes only (paper §4.1.2: "connected
    without input or output splits in between").
    """
    pairs = []
    for n in nodes:
        if n["op"] != "conv":
            continue
        cur = n
        ok = True
        while True:
            cons = consumers(nodes, cur["id"])
            if len(cons) != 1:
                ok = False
                break
            nxt = cons[0]
            if nxt["op"] in ("bn", "act"):
                cur = nxt
                continue
            if nxt["op"] == "conv":
                pairs.append((n["id"], nxt["id"]))
            ok = False
            break
        _ = ok
    return pairs
