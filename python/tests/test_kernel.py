"""L1 kernel vs pure-jnp oracle — the core correctness signal.

Hypothesis sweeps tile-compatible shapes and epilogue configurations;
every case must match ref.py within float tolerance.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fq_matmul import (fq_matmul, mxu_utilization, pick_tile,
                                       supported, vmem_bytes, _TM_CHOICES,
                                       _TN_CHOICES)
from compile.kernels.ref import fake_quant, fq_matmul_ref

RNG = np.random.default_rng(0)


def run_both(m, k, n, cfg, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, k)).astype(np.float32)
    w = rng.normal(size=(k, n)).astype(np.float32)
    b = rng.normal(size=(n,)).astype(np.float32)
    cfg = np.asarray(cfg, np.float32)
    got = np.asarray(fq_matmul(jnp.array(x), jnp.array(w), jnp.array(b),
                               jnp.array(cfg)))
    want = np.asarray(fq_matmul_ref(x, w, b, cfg))
    return got, want


def plain_cfg():
    return [-1e30, 1e30, 1.0, 0.0, 0.0, 0, 0, 0]


class TestKernelBasic:
    def test_plain_matmul(self):
        got, want = run_both(64, 24, 16, plain_cfg())
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_relu6_epilogue(self):
        got, want = run_both(32, 8, 8, [0.0, 6.0, 1.0, 0.0, 0.0, 0, 0, 0])
        assert got.min() >= 0.0 and got.max() <= 6.0
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_int8_fakequant_epilogue(self):
        cfg = [0.0, 6.0, 6.0 / 255, 0.0, 256.0, 0, 0, 0]
        got, want = run_both(32, 16, 8, cfg)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
        # outputs land on the quantisation grid
        scale = cfg[2]
        q = got / scale
        np.testing.assert_allclose(q, np.round(q), atol=1e-3)

    def test_multiple_grid_tiles(self):
        got, want = run_both(512, 40, 128, plain_cfg())
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_untileable_rejected(self):
        assert not supported(10, 4)
        with pytest.raises(AssertionError):
            run_both(10, 8, 4, plain_cfg())


@settings(max_examples=25, deadline=None)
@given(
    mi=st.sampled_from([16, 32, 64, 128, 256]),
    k=st.integers(1, 48),
    ni=st.sampled_from([8, 16, 24, 40, 64, 128]),
    bits=st.sampled_from([0, 2, 4, 8]),
    clip6=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_property(mi, k, ni, bits, clip6, seed):
    n_levels = float(2**bits) if bits else 0.0
    hi = 6.0 if clip6 else 1e30
    lo = 0.0 if clip6 else -1e30
    scale = (hi - lo) / max(n_levels - 1, 1) if clip6 and bits else 0.05
    cfg = [lo, hi, scale, 3.0 if bits else 0.0, n_levels, 0, 0, 0]
    got, want = run_both(mi, k, ni, cfg, seed=seed % 1000)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestFakeQuantOracle:
    def test_identity_when_disabled(self):
        x = np.linspace(-3, 3, 13).astype(np.float32)
        np.testing.assert_array_equal(np.asarray(fake_quant(x, 1.0, 0.0, 0.0)), x)

    def test_ties_to_even(self):
        assert float(fake_quant(jnp.float32(0.5), 1.0, 0.0, 16.0)) == 0.0
        assert float(fake_quant(jnp.float32(1.5), 1.0, 0.0, 16.0)) == 2.0

    def test_clamps_to_grid(self):
        y = float(fake_quant(jnp.float32(-100.0), 0.1, 10.0, 256.0))
        assert y == pytest.approx((0 - 10) * 0.1)


class TestTilingModel:
    def test_pick_tile_divides(self):
        for d in [16, 64, 80, 96, 1024, 16384]:
            t = pick_tile(d, _TM_CHOICES)
            assert t and d % t == 0

    def test_vmem_under_budget(self):
        # largest zoo tiling must fit a 16 MiB VMEM with ample headroom
        assert vmem_bytes(16384, 160, 64) < 4 * 2**20

    def test_mxu_utilization_bounds(self):
        u = mxu_utilization(1024, 64, 64)
        assert 0.0 < u <= 1.0
