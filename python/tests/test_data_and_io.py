"""Dataset generators + container round-trips + corruption invariance."""

import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import corrupt, data as D, dfqm, layers, specs


class TestData:
    def test_classification_shapes_and_determinism(self):
        x1, y1 = D.make_classification(64, seed=5)
        x2, y2 = D.make_classification(64, seed=5)
        assert x1.shape == (64, 3, D.IMG, D.IMG)
        assert x1.dtype == np.float32 and y1.dtype == np.int32
        np.testing.assert_array_equal(x1, x2)
        np.testing.assert_array_equal(y1, y2)
        assert 0 <= y1.min() and y1.max() < D.CLS_CLASSES
        assert 0.0 <= x1.min() and x1.max() <= 1.0

    def test_classification_class_balanceish(self):
        _, y = D.make_classification(2000, seed=1)
        counts = np.bincount(y, minlength=D.CLS_CLASSES)
        assert counts.min() > 100  # roughly uniform

    def test_segmentation_masks_consistent(self):
        x, seg = D.make_segmentation(32, seed=2)
        assert seg.shape == (32, D.IMG, D.IMG)
        assert seg.max() < D.SEG_CLASSES
        # at least one foreground pixel per image
        assert all((seg[i] > 0).any() for i in range(32))

    def test_detection_boxes_valid(self):
        x, b = D.make_detection(64, seed=3)
        assert b.shape == (64, D.DET_MAX_OBJ, 5)
        valid = b[..., 0] >= 0
        assert valid.any(axis=1).all(), "every image has >= 1 object"
        assert (b[..., 3][valid] > b[..., 1][valid]).all()
        assert (b[..., 4][valid] > b[..., 2][valid]).all()

    def test_shape_masks_disjoint_shapes(self):
        m1 = D.shape_mask("circle", [16], [16], [8])
        m2 = D.shape_mask("ring", [16], [16], [8])
        assert m1.sum() > m2.sum() > 0


class TestContainers:
    def test_dataset_roundtrip(self):
        x, y = D.make_classification(8, seed=7)
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "t.dfqd")
            dfqm.write_dataset(p, "t", "classification", {"x": x, "y": y})
            hdr, arrs = dfqm.read(p)
            assert hdr["task"] == "classification"
            np.testing.assert_array_equal(arrs["x"], x)
            np.testing.assert_array_equal(arrs["y"], y)

    def test_model_roundtrip(self):
        nodes, outputs, task, shapes, input_shape = specs.build("micronet_v1")
        params = layers.init_params(jax.random.PRNGKey(0), shapes, nodes)
        np_params = {k: np.asarray(v, np.float32) for k, v in params.items()}
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "m.dfqm")
            dfqm.write_model(p, "m", task, input_shape, D.CLS_CLASSES,
                             nodes, outputs, np_params)
            hdr, arrs = dfqm.read(p)
            assert hdr["nodes"] == nodes
            assert hdr["outputs"] == list(outputs)
            for k, v in np_params.items():
                np.testing.assert_array_equal(arrs[k], v)

    def test_alignment(self):
        # blobs are 64-byte aligned regardless of sizes
        arrs = {"a": np.ones(3, np.float32), "b": np.ones(17, np.int32)}
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "t.dfqd")
            dfqm.write(p, b"DFQD", {"kind": "dataset", "name": "t",
                                    "task": "classification"}, arrs)
            hdr, back = dfqm.read(p)
            for k in arrs:
                off = hdr["arrays"][k]["offset"]
                assert off % 64 == 0
                np.testing.assert_array_equal(back[k], arrs[k])


class TestCorruption:
    @pytest.mark.parametrize("arch", ["micronet_v2", "microresnet18"])
    def test_function_preserving(self, arch):
        nodes, outputs, task, shapes, input_shape = specs.build(arch)
        params = layers.init_params(jax.random.PRNGKey(1), shapes, nodes)
        params = {k: np.asarray(v) for k, v in params.items()}
        x = np.asarray(
            jax.random.uniform(jax.random.PRNGKey(2), (64, *input_shape)),
            np.float32)
        y0, _, _ = layers.forward(nodes, outputs, params,
                                  jnp.asarray(x[:8]), False)
        cor = corrupt.corrupt(nodes, outputs, params, x, seed=5)
        y1, _, _ = layers.forward(nodes, outputs, cor,
                                  jnp.asarray(x[:8]), False)
        d = float(jnp.max(jnp.abs(y0[0] - y1[0])))
        scale = float(jnp.max(jnp.abs(y0[0]))) + 1e-6
        assert d / scale < 5e-3, f"corruption changed the function: {d}"

    def test_actually_corrupts_ranges(self):
        nodes, outputs, task, shapes, input_shape = specs.build("micronet_v2")
        params = layers.init_params(jax.random.PRNGKey(1), shapes, nodes)
        params = {k: np.asarray(v) for k, v in params.items()}
        x = np.asarray(
            jax.random.uniform(jax.random.PRNGKey(2), (64, *input_shape)),
            np.float32)
        cor = corrupt.corrupt(nodes, outputs, dict(params), x, seed=5)
        # at least one conv weight tensor sees large per-channel disparity
        changed = 0
        for a_id, b_id in specs.cle_pairs(nodes):
            b = next(n for n in nodes if n["id"] == b_id)
            w0, w1 = params[b["w"]], cor[b["w"]]
            if not np.allclose(w0, w1):
                changed += 1
        assert changed >= 5
