"""L2 model tests: spec building, folding parity, quantsim semantics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import data as D
from compile import layers, specs
from compile.model import (act_sites, fold_params, fold_spec,
                           quantsim_forward, weight_args)

ARCHS = list(specs.ARCHS)


def build_random(arch, seed=0):
    nodes, outputs, task, shapes, input_shape = specs.build(arch)
    params = layers.init_params(jax.random.PRNGKey(seed), shapes, nodes)
    # non-trivial BN statistics
    for n in nodes:
        if n["op"] == "bn":
            k = jax.random.fold_in(jax.random.PRNGKey(seed + 1), n["id"])
            k1, k2, k3, k4 = jax.random.split(k, 4)
            params[n["mean"]] = 0.3 * jax.random.normal(k1, (n["ch"],))
            params[n["var"]] = jnp.exp(0.3 * jax.random.normal(k2, (n["ch"],)))
            params[n["gamma"]] = 1.0 + 0.2 * jax.random.normal(k3, (n["ch"],))
            params[n["beta"]] = 0.2 * jax.random.normal(k4, (n["ch"],))
    return nodes, outputs, task, params, input_shape


def fp32_qcfg(folded):
    sites = act_sites(folded)
    q = np.zeros((len(sites), 4), np.float32)
    for i, s in enumerate(sites):
        if s["node"] == "input" or s["op"] == "add":
            q[i, 3] = 1e30
        else:
            q[i, 3] = 6.0 if s["kind"] == "relu6" else 1e30
    return q


class TestSpecs:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_builds_and_is_wellformed(self, arch):
        nodes, outputs, task, shapes, input_shape = specs.build(arch)
        ids = [n["id"] for n in nodes]
        assert ids == sorted(ids) and len(set(ids)) == len(ids)
        for n in nodes:
            for i in n["inputs"]:
                assert i < n["id"], "inputs must precede the node"
        assert all(o in ids for o in outputs)

    def test_cle_pairs_exist(self):
        nodes, *_ = specs.build("micronet_v2")
        pairs = specs.cle_pairs(nodes)
        # 5 blocks x 2 pairs inside each: (expand,dw), (dw,project)
        assert len(pairs) == 10

    def test_v1_chain_has_many_pairs(self):
        nodes, *_ = specs.build("micronet_v1")
        assert len(specs.cle_pairs(nodes)) == 10  # 11 convs chained

    def test_channels_multiple_of_8(self):
        # pallas tiling requirement for every pointwise conv
        for arch in ARCHS:
            nodes, *_ = specs.build(arch)
            for n in nodes:
                if n["op"] == "conv" and n["k"] == 1 and n["groups"] == 1:
                    if n["out_ch"] % 8 != 0:
                        # only the tiny logit heads are exempt (jnp path)
                        assert n["out_ch"] <= 8


class TestFolding:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_quantsim_matches_train_forward(self, arch):
        nodes, outputs, task, params, input_shape = build_random(arch)
        x = jax.random.uniform(jax.random.PRNGKey(7), (2, *input_shape))
        ref, _, _ = layers.forward(nodes, outputs, params, x, False)
        folded, remap = fold_spec(nodes)
        weights, _ = fold_params(nodes, params)
        got = quantsim_forward(folded, outputs, remap,
                               [jnp.asarray(w) for w in weights], x,
                               jnp.asarray(fp32_qcfg(folded)))
        for a, b in zip(ref, got):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=2e-4)

    def test_fold_removes_bn_and_adds_bias(self):
        nodes, *_ = specs.build("micronet_v2")
        folded, _ = fold_spec(nodes)
        assert not any(n["op"] == "bn" for n in folded)
        for n in folded:
            if n["op"] == "conv":
                assert n["b"] is not None

    def test_weight_args_alternate_w_b(self):
        nodes, *_ = specs.build("micronet_v1")
        folded, _ = fold_spec(nodes)
        order = weight_args(folded)
        kinds = [k for _, k in order]
        assert kinds[::2] == ["weight"] * (len(order) // 2)
        assert kinds[1::2] == ["bias"] * (len(order) // 2)

    def test_act_sites_start_with_input(self):
        nodes, *_ = specs.build("micronet_v2")
        folded, _ = fold_spec(nodes)
        sites = act_sites(folded)
        assert sites[0]["node"] == "input"
        n_act = sum(1 for n in folded if n["op"] in ("act", "add"))
        assert len(sites) == 1 + n_act


class TestQuantsimSemantics:
    def test_act_quant_reduces_precision(self):
        nodes, outputs, task, params, input_shape = build_random(
            "micronet_v1", seed=3)
        x = jax.random.uniform(jax.random.PRNGKey(9), (2, *input_shape))
        folded, remap = fold_spec(nodes)
        weights = [jnp.asarray(w) for w in fold_params(nodes, params)[0]]
        q = fp32_qcfg(folded)
        y_fp = quantsim_forward(folded, outputs, remap, weights, x,
                                jnp.asarray(q))
        # coarse 2-bit activations everywhere
        q2 = q.copy()
        q2[:, 0] = 0.5   # scale
        q2[:, 1] = 4.0   # zp
        q2[:, 2] = 8.0   # n_levels
        y_q = quantsim_forward(folded, outputs, remap, weights, x,
                               jnp.asarray(q2))
        d = float(jnp.max(jnp.abs(y_fp[0] - y_q[0])))
        assert d > 1e-3, "activation quantisation had no effect"

    def test_detection_output_shape(self):
        nodes, outputs, task, params, input_shape = build_random("microssd")
        folded, remap = fold_spec(nodes)
        weights = [jnp.asarray(w) for w in fold_params(nodes, params)[0]]
        x = jnp.zeros((2, *input_shape))
        (y,) = quantsim_forward(folded, outputs, remap, weights, x,
                                jnp.asarray(fp32_qcfg(folded)))
        assert y.shape == (2, D.DET_CLASSES + 1 + 4, 4, 4)

    def test_segmentation_output_shape(self):
        nodes, outputs, task, params, input_shape = build_random(
            "microdeeplab")
        folded, remap = fold_spec(nodes)
        weights = [jnp.asarray(w) for w in fold_params(nodes, params)[0]]
        x = jnp.zeros((2, *input_shape))
        (y,) = quantsim_forward(folded, outputs, remap, weights, x,
                                jnp.asarray(fp32_qcfg(folded)))
        assert y.shape == (2, D.SEG_CLASSES, D.IMG, D.IMG)
